//! Runtime-dispatched SIMD kernels for the native backend.
//!
//! Everything hot in the forward pass funnels through here when the host
//! CPU has AVX2+FMA: the f32 GEMM microkernel, the int8 (maddubs) GEMM,
//! per-row activation quantization, and a vectorized tanh-GELU. Dispatch
//! is decided once per process (`active_kernel`, cached in a `OnceLock`)
//! from CPUID, with a `DATAMUX_FORCE_SCALAR=1` override so the scalar
//! fallback arm is exercisable on any host (CI runs a leg with it set).
//!
//! The scalar fallbacks live in `gemm.rs` (f32) and `quant.rs` (int8);
//! both pairs of arms are kept bitwise-comparable where the math allows
//! (int8: identical integer accumulation and a shared `dequant` epilogue;
//! f32: same per-element rounding in the quantizer via ties-to-even).
#![allow(
    clippy::too_many_arguments,
    clippy::excessive_precision,
    clippy::needless_range_loop
)]

use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

#[cfg(target_arch = "x86_64")]
use super::forward::{gelu, GELU_C};
#[cfg(target_arch = "x86_64")]
use super::quant::{dequant, QuantMat};

/// Which GEMM/quant kernel family this process selected at startup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// `std::arch` AVX2+FMA microkernels (x86_64 with CPUID support).
    Avx2Fma,
    /// Portable blocked-scalar kernels — non-x86_64 hosts, CPUs without
    /// AVX2/FMA, or a `DATAMUX_FORCE_SCALAR=1` override.
    Scalar,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Avx2Fma => "avx2+fma",
            Kernel::Scalar => "scalar",
        }
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The kernel family every dispatch site in this process uses. Decided
/// once; the env override is read at first call, not per call.
pub fn active_kernel() -> Kernel {
    static KERNEL: OnceLock<Kernel> = OnceLock::new();
    *KERNEL.get_or_init(detect)
}

fn forced_scalar() -> bool {
    match std::env::var("DATAMUX_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

fn detect() -> Kernel {
    if forced_scalar() {
        return Kernel::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Kernel::Avx2Fma;
        }
    }
    Kernel::Scalar
}

// ---------------------------------------------------------------- f32 GEMM

/// Column-tile width: keeps NC rows of bt resident in L1/L2 across the
/// whole m sweep (matches the scalar kernel's blocking).
#[cfg(target_arch = "x86_64")]
const NC: usize = 64;
/// Rows of bt (= output columns) processed together per inner kernel.
#[cfg(target_arch = "x86_64")]
const NR: usize = 4;

/// AVX2+FMA `C = A * B^T (+ bias)`. Same contract as `gemm::gemm_bt`:
/// `a` is (m,k) row-major, `bt` is (n,k) row-major, `c` is (m,n).
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 and FMA (see `active_kernel`)
/// and that the slice lengths match the dimensions (asserted by the
/// dispatching wrapper).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn gemm_bt_f32_avx2(
    a: &[f32],
    bt: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let mut jb = 0usize;
    while jb < n {
        let je = (jb + NC).min(n);
        for i in 0..m {
            let ar = a.as_ptr().add(i * k);
            let cr = c.as_mut_ptr().add(i * n);
            let mut j = jb;
            while j + NR <= je {
                let b0 = bt.as_ptr().add(j * k);
                let b1 = bt.as_ptr().add((j + 1) * k);
                let b2 = bt.as_ptr().add((j + 2) * k);
                let b3 = bt.as_ptr().add((j + 3) * k);
                let (s0, s1, s2, s3) = dot4(ar, b0, b1, b2, b3, k);
                match bias {
                    Some(b) => {
                        *cr.add(j) = s0 + b[j];
                        *cr.add(j + 1) = s1 + b[j + 1];
                        *cr.add(j + 2) = s2 + b[j + 2];
                        *cr.add(j + 3) = s3 + b[j + 3];
                    }
                    None => {
                        *cr.add(j) = s0;
                        *cr.add(j + 1) = s1;
                        *cr.add(j + 2) = s2;
                        *cr.add(j + 3) = s3;
                    }
                }
                j += NR;
            }
            while j < je {
                let s = dot1(ar, bt.as_ptr().add(j * k), k);
                *cr.add(j) = s + bias.map_or(0.0, |b| b[j]);
                j += 1;
            }
        }
        jb = je;
    }
}

/// One A row against four B^T rows; 4 independent FMA chains.
///
/// # Safety
/// `a` and each `b*` must be valid for `k` f32 reads, and the CPU must
/// support AVX2+FMA (guaranteed by the dispatching kernel).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot4(
    a: *const f32,
    b0: *const f32,
    b1: *const f32,
    b2: *const f32,
    b3: *const f32,
    k: usize,
) -> (f32, f32, f32, f32) {
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut p = 0usize;
    while p + 8 <= k {
        let av = _mm256_loadu_ps(a.add(p));
        acc0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b0.add(p)), acc0);
        acc1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b1.add(p)), acc1);
        acc2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b2.add(p)), acc2);
        acc3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b3.add(p)), acc3);
        p += 8;
    }
    let mut s0 = hsum_ps(acc0);
    let mut s1 = hsum_ps(acc1);
    let mut s2 = hsum_ps(acc2);
    let mut s3 = hsum_ps(acc3);
    while p < k {
        let av = *a.add(p);
        s0 += av * *b0.add(p);
        s1 += av * *b1.add(p);
        s2 += av * *b2.add(p);
        s3 += av * *b3.add(p);
        p += 1;
    }
    (s0, s1, s2, s3)
}

/// One A row against one B^T row.
///
/// # Safety
/// `a` and `b` must be valid for `k` f32 reads, and the CPU must
/// support AVX2+FMA (guaranteed by the dispatching kernel).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot1(a: *const f32, b: *const f32, k: usize) -> f32 {
    let mut acc = _mm256_setzero_ps();
    let mut p = 0usize;
    while p + 8 <= k {
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(p)), _mm256_loadu_ps(b.add(p)), acc);
        p += 8;
    }
    let mut s = hsum_ps(acc);
    while p < k {
        s += *a.add(p) * *b.add(p);
        p += 1;
    }
    s
}

/// Deterministic horizontal sum of 8 lanes (fixed reduction order, so
/// results are reproducible run to run and thread-count independent).
///
/// # Safety
/// Register-only math; unsafe solely for the AVX2+FMA target feature,
/// which the dispatching kernel guarantees.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum_ps(v: __m256) -> f32 {
    let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_movehdup_ps(s));
    _mm_cvtss_f32(s)
}

// ---------------------------------------------------------------- int8 GEMM

/// AVX2 int8 `C = dequant(Aq * Wq^T) (+ bias)`. `aq` is (m,k) row-major
/// biased-u8 activations (value = q+128), `w` holds (n,k) row-major int8
/// weights with per-output-channel scales and column sums.
///
/// Integer accumulation is exact, and the f32 epilogue is the shared
/// `quant::dequant`, so this arm is bitwise-identical to
/// `quant::gemm_bt_q8_scalar`.
///
/// # Safety
/// Caller must ensure AVX2 support and matching slice lengths.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_bt_q8_avx2(
    aq: &[u8],
    ascale: &[f32],
    w: &QuantMat,
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let mut jb = 0usize;
    while jb < n {
        let je = (jb + NC).min(n);
        for i in 0..m {
            let ar = aq.as_ptr().add(i * k);
            let cr = c.as_mut_ptr().add(i * n);
            let sa = ascale[i];
            let mut j = jb;
            while j + NR <= je {
                let w0 = w.q.as_ptr().add(j * k);
                let w1 = w.q.as_ptr().add((j + 1) * k);
                let w2 = w.q.as_ptr().add((j + 2) * k);
                let w3 = w.q.as_ptr().add((j + 3) * k);
                let (d0, d1, d2, d3) = qdot4(ar, w0, w1, w2, w3, k);
                match bias {
                    Some(b) => {
                        *cr.add(j) = dequant(d0, w.wsum[j], sa, w.scales[j], b[j]);
                        *cr.add(j + 1) = dequant(d1, w.wsum[j + 1], sa, w.scales[j + 1], b[j + 1]);
                        *cr.add(j + 2) = dequant(d2, w.wsum[j + 2], sa, w.scales[j + 2], b[j + 2]);
                        *cr.add(j + 3) = dequant(d3, w.wsum[j + 3], sa, w.scales[j + 3], b[j + 3]);
                    }
                    None => {
                        *cr.add(j) = dequant(d0, w.wsum[j], sa, w.scales[j], 0.0);
                        *cr.add(j + 1) = dequant(d1, w.wsum[j + 1], sa, w.scales[j + 1], 0.0);
                        *cr.add(j + 2) = dequant(d2, w.wsum[j + 2], sa, w.scales[j + 2], 0.0);
                        *cr.add(j + 3) = dequant(d3, w.wsum[j + 3], sa, w.scales[j + 3], 0.0);
                    }
                }
                j += NR;
            }
            while j < je {
                let d = qdot1(ar, w.q.as_ptr().add(j * k), k);
                let b = match bias {
                    Some(b) => b[j],
                    None => 0.0,
                };
                *cr.add(j) = dequant(d, w.wsum[j], sa, w.scales[j], b);
                j += 1;
            }
        }
        jb = je;
    }
}

/// One u8 activation row against four i8 weight rows. `maddubs` pairs
/// u8×i8 into i16 (weights are clamped to ±63 so the pair-sum cannot
/// saturate: 2·255·63 = 32130 < i16::MAX), then `madd` widens to i32.
///
/// # Safety
/// `a` and each `w*` must be valid for `k` byte reads, and the CPU must
/// support AVX2 (guaranteed by the dispatching kernel).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn qdot4(
    a: *const u8,
    w0: *const i8,
    w1: *const i8,
    w2: *const i8,
    w3: *const i8,
    k: usize,
) -> (i32, i32, i32, i32) {
    let ones = _mm256_set1_epi16(1);
    let mut acc0 = _mm256_setzero_si256();
    let mut acc1 = _mm256_setzero_si256();
    let mut acc2 = _mm256_setzero_si256();
    let mut acc3 = _mm256_setzero_si256();
    let mut p = 0usize;
    while p + 32 <= k {
        let av = _mm256_loadu_si256(a.add(p) as *const __m256i);
        let m0 = _mm256_maddubs_epi16(av, _mm256_loadu_si256(w0.add(p) as *const __m256i));
        let m1 = _mm256_maddubs_epi16(av, _mm256_loadu_si256(w1.add(p) as *const __m256i));
        let m2 = _mm256_maddubs_epi16(av, _mm256_loadu_si256(w2.add(p) as *const __m256i));
        let m3 = _mm256_maddubs_epi16(av, _mm256_loadu_si256(w3.add(p) as *const __m256i));
        acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(m0, ones));
        acc1 = _mm256_add_epi32(acc1, _mm256_madd_epi16(m1, ones));
        acc2 = _mm256_add_epi32(acc2, _mm256_madd_epi16(m2, ones));
        acc3 = _mm256_add_epi32(acc3, _mm256_madd_epi16(m3, ones));
        p += 32;
    }
    let mut s0 = hsum_epi32(acc0);
    let mut s1 = hsum_epi32(acc1);
    let mut s2 = hsum_epi32(acc2);
    let mut s3 = hsum_epi32(acc3);
    while p < k {
        let av = *a.add(p) as i32;
        s0 += av * *w0.add(p) as i32;
        s1 += av * *w1.add(p) as i32;
        s2 += av * *w2.add(p) as i32;
        s3 += av * *w3.add(p) as i32;
        p += 1;
    }
    (s0, s1, s2, s3)
}

/// One u8 activation row against one i8 weight row.
///
/// # Safety
/// `a` and `w` must be valid for `k` byte reads, and the CPU must
/// support AVX2 (guaranteed by the dispatching kernel).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn qdot1(a: *const u8, w: *const i8, k: usize) -> i32 {
    let ones = _mm256_set1_epi16(1);
    let mut acc = _mm256_setzero_si256();
    let mut p = 0usize;
    while p + 32 <= k {
        let av = _mm256_loadu_si256(a.add(p) as *const __m256i);
        let mu = _mm256_maddubs_epi16(av, _mm256_loadu_si256(w.add(p) as *const __m256i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(mu, ones));
        p += 32;
    }
    let mut s = hsum_epi32(acc);
    while p < k {
        s += (*a.add(p) as i32) * (*w.add(p) as i32);
        p += 1;
    }
    s
}

/// Horizontal sum of 8 i32 lanes.
///
/// # Safety
/// Register-only math; unsafe solely for the AVX2 target feature, which
/// the dispatching kernel guarantees.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256(v, 1));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0x4E));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0xB1));
    _mm_cvtsi128_si32(s)
}

// --------------------------------------------------- activation quantization

/// Symmetric per-row activation quantization to biased u8 (`q+128`).
/// Returns the row scale `amax/127`. Bitwise-identical to
/// `quant::quantize_row_scalar`: `_mm256_cvtps_epi32` rounds to nearest
/// even under the default MXCSR, matching `round_ties_even` in the
/// scalar arm.
///
/// # Safety
/// Caller must ensure AVX2+FMA support and `out.len() >= x.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn quantize_row_avx2(x: &[f32], out: &mut [u8]) -> f32 {
    let k = x.len();
    let sign = _mm256_set1_ps(-0.0);
    let mut mx = _mm256_setzero_ps();
    let mut p = 0usize;
    while p + 8 <= k {
        mx = _mm256_max_ps(mx, _mm256_andnot_ps(sign, _mm256_loadu_ps(x.as_ptr().add(p))));
        p += 8;
    }
    let mut amax = hmax_ps(mx);
    while p < k {
        amax = amax.max(x[p].abs());
        p += 1;
    }
    if amax <= 0.0 {
        out[..k].fill(128);
        return 0.0;
    }
    let inv = 127.0 / amax;
    let invv = _mm256_set1_ps(inv);
    let bias128 = _mm256_set1_epi32(128);
    let optr = out.as_mut_ptr();
    p = 0;
    while p + 8 <= k {
        let q = _mm256_cvtps_epi32(_mm256_mul_ps(_mm256_loadu_ps(x.as_ptr().add(p)), invv));
        let q = _mm256_add_epi32(q, bias128);
        let lo = _mm256_castsi256_si128(q);
        let hi = _mm256_extracti128_si256(q, 1);
        let w16 = _mm_packs_epi32(lo, hi);
        let w8 = _mm_packus_epi16(w16, w16);
        _mm_storel_epi64(optr.add(p) as *mut __m128i, w8);
        p += 8;
    }
    while p < k {
        out[p] = ((x[p] * inv).round_ties_even() as i32 + 128) as u8;
        p += 1;
    }
    amax / 127.0
}

/// Horizontal max of 8 f32 lanes.
///
/// # Safety
/// Register-only math; unsafe solely for the AVX2+FMA target feature,
/// which the dispatching kernel guarantees.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn hmax_ps(v: __m256) -> f32 {
    let s = _mm_max_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
    let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_max_ss(s, _mm_movehdup_ps(s));
    _mm_cvtss_f32(s)
}

// ------------------------------------------------------------------- GELU

/// Vectorized tanh-GELU over a whole buffer, matching `forward::gelu`'s
/// formula. tanh is computed as `1 - 2/(e^{2t}+1)` with a polynomial
/// `exp` (Cephes coefficients), accurate to ~1 ulp over the clamped
/// range — within the forward pass's existing 1e-3 parity budget.
///
/// # Safety
/// Caller must ensure AVX2+FMA support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn gelu_avx2(xs: &mut [f32]) {
    let half = _mm256_set1_ps(0.5);
    let one = _mm256_set1_ps(1.0);
    let two = _mm256_set1_ps(2.0);
    let c_cube = _mm256_set1_ps(0.044_715);
    let c_gelu = _mm256_set1_ps(GELU_C);
    let len = xs.len();
    let ptr = xs.as_mut_ptr();
    let mut p = 0usize;
    while p + 8 <= len {
        let x = _mm256_loadu_ps(ptr.add(p));
        let x2 = _mm256_mul_ps(x, x);
        // t = GELU_C * (x + 0.044715 x^3) = GELU_C * x * (1 + 0.044715 x^2)
        let inner = _mm256_mul_ps(x, _mm256_fmadd_ps(c_cube, x2, one));
        let t = _mm256_mul_ps(c_gelu, inner);
        // tanh(t) = 1 - 2/(exp(2t) + 1)
        let e = exp_ps(_mm256_add_ps(t, t));
        let tanh = _mm256_sub_ps(one, _mm256_div_ps(two, _mm256_add_ps(e, one)));
        let y = _mm256_mul_ps(_mm256_mul_ps(half, x), _mm256_add_ps(one, tanh));
        _mm256_storeu_ps(ptr.add(p), y);
        p += 8;
    }
    for v in xs[p..].iter_mut() {
        *v = gelu(*v);
    }
}

/// Polynomial exp over 8 lanes (Cephes `expf` scheme: range-reduce by
/// log2(e), degree-5 polynomial, scale by 2^n through the exponent bits).
///
/// # Safety
/// Register-only math; unsafe solely for the AVX2+FMA target feature,
/// which the dispatching kernel guarantees.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn exp_ps(x: __m256) -> __m256 {
    let one = _mm256_set1_ps(1.0);
    let x = _mm256_min_ps(x, _mm256_set1_ps(88.3762626647949));
    let x = _mm256_max_ps(x, _mm256_set1_ps(-88.3762626647949));
    // n = floor(x * log2(e) + 0.5); x -= n*ln2 in two exact-ish steps
    let fx = _mm256_floor_ps(_mm256_fmadd_ps(
        x,
        _mm256_set1_ps(std::f32::consts::LOG2_E),
        _mm256_set1_ps(0.5),
    ));
    let x = _mm256_fnmadd_ps(fx, _mm256_set1_ps(0.693359375), x);
    let x = _mm256_fnmadd_ps(fx, _mm256_set1_ps(-2.12194440e-4), x);
    let x2 = _mm256_mul_ps(x, x);
    let mut y = _mm256_set1_ps(1.9875691500E-4);
    y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(1.3981999507E-3));
    y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(8.3334519073E-3));
    y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(4.1665795894E-2));
    y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(1.6666665459E-1));
    y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(5.0000001201E-1));
    y = _mm256_fmadd_ps(y, x2, _mm256_add_ps(x, one));
    // 2^n via the float exponent field
    let pow2n = _mm256_castsi256_ps(_mm256_slli_epi32(
        _mm256_add_epi32(_mm256_cvttps_epi32(fx), _mm256_set1_epi32(0x7f)),
        23,
    ));
    _mm256_mul_ps(y, pow2n)
}

// ---------------------------------------------------------- flash attention

/// K/V block width for the tiled online-softmax attention. Scores are
/// materialized `ATTN_TILE` at a time per query row, so attention scratch
/// is constant in `input_len` instead of quadratic.
pub(crate) const ATTN_TILE: usize = 16;

/// Scalar twin of [`exp_ps`]: the same Cephes range reduction and
/// degree-5 polynomial, evaluated with `mul_add` so every step rounds
/// exactly like the corresponding vector FMA — one lane of `exp_ps` and
/// this function agree bitwise. Both flash-attention arms use it (the
/// scalar arm throughout, the AVX2 arm for sub-8-lane tile tails), which
/// keeps the two arms' probabilities identical for identical scores.
#[inline]
pub(crate) fn exp_approx(x: f32) -> f32 {
    let x = x.clamp(-88.3762626647949, 88.3762626647949);
    let fx = x.mul_add(std::f32::consts::LOG2_E, 0.5).floor();
    let x = fx.mul_add(-0.693359375, x);
    let x = fx.mul_add(2.12194440e-4, x);
    let x2 = x * x;
    let mut y = 1.9875691500e-4f32;
    y = y.mul_add(x, 1.3981999507e-3);
    y = y.mul_add(x, 8.3334519073e-3);
    y = y.mul_add(x, 4.1665795894e-2);
    y = y.mul_add(x, 1.6666665459e-1);
    y = y.mul_add(x, 5.0000001201e-1);
    y = y.mul_add(x2, x + 1.0);
    y * f32::from_bits(((fx as i32 + 0x7f) as u32) << 23)
}

/// One query row of tiled flash attention, scalar arm. `qkv` is the fused
/// projection stream with row stride `stride` laid out `[q | k | v]`;
/// `qoff` addresses this row's query head slice, `kbase`/`vbase` the head's
/// K/V column at sequence position 0. K/V blocks of `ATTN_TILE` positions
/// stream through an online-softmax accumulator (running max `m`, running
/// mass `l`, unnormalized context in `out`): when a block raises the max,
/// the accumulated state is rescaled by `exp(m_old - m_new)` instead of
/// revisiting earlier positions. Only `stile` (`ATTN_TILE` floats) is ever
/// materialized — no `li×li` scores block exists at any point.
// lint: hot-path
pub(crate) fn flash_attn_row_scalar(
    qkv: &[f32],
    qoff: usize,
    kbase: usize,
    vbase: usize,
    stride: usize,
    li: usize,
    dh: usize,
    scale: f32,
    stile: &mut [f32],
    out: &mut [f32],
) {
    let q = &qkv[qoff..qoff + dh];
    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    out.fill(0.0);
    let mut j0 = 0usize;
    while j0 < li {
        let tl = (j0 + ATTN_TILE).min(li) - j0;
        // QK^T scores for this tile: 4 K rows per pass, independent chains
        let mut t = 0usize;
        while t + 4 <= tl {
            let k0 = &qkv[kbase + (j0 + t) * stride..][..dh];
            let k1 = &qkv[kbase + (j0 + t + 1) * stride..][..dh];
            let k2 = &qkv[kbase + (j0 + t + 2) * stride..][..dh];
            let k3 = &qkv[kbase + (j0 + t + 3) * stride..][..dh];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for u in 0..dh {
                let qv = q[u];
                s0 += qv * k0[u];
                s1 += qv * k1[u];
                s2 += qv * k2[u];
                s3 += qv * k3[u];
            }
            stile[t] = s0 * scale;
            stile[t + 1] = s1 * scale;
            stile[t + 2] = s2 * scale;
            stile[t + 3] = s3 * scale;
            t += 4;
        }
        while t < tl {
            let kr = &qkv[kbase + (j0 + t) * stride..][..dh];
            let mut s = 0.0f32;
            for u in 0..dh {
                s += q[u] * kr[u];
            }
            stile[t] = s * scale;
            t += 1;
        }
        // online softmax: rescale the running state when the max grows
        let mut mt = stile[0];
        for &sv in &stile[1..tl] {
            mt = mt.max(sv);
        }
        if mt > m {
            if m > f32::NEG_INFINITY {
                let r = exp_approx(m - mt);
                l *= r;
                for v in out.iter_mut() {
                    *v *= r;
                }
            }
            m = mt;
        }
        for t in 0..tl {
            let p = exp_approx(stile[t] - m);
            l += p;
            let vr = &qkv[vbase + (j0 + t) * stride..][..dh];
            for u in 0..dh {
                out[u] += p * vr[u];
            }
        }
        j0 += ATTN_TILE;
    }
    let inv = 1.0 / l;
    for v in out.iter_mut() {
        *v *= inv;
    }
}

/// AVX2 arm of one flash-attention query row: `dot4`/`dot1` for the QK^T
/// scores, `exp_ps` for full 8-lane probability groups with an
/// [`exp_approx`] tail (bitwise-identical per lane), broadcast-FMA PV
/// accumulation with a `mul_add` scalar tail, same online-softmax state
/// machine as the scalar arm.
///
/// # Safety
/// Caller must ensure AVX2+FMA support (see `active_kernel`) and that
/// `qoff + dh`, `kbase + (li-1)*stride + dh` and `vbase + (li-1)*stride + dh`
/// stay within `qkv`; `stile` must hold at least `ATTN_TILE` floats and
/// `out` exactly `dh`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn flash_attn_row_avx2(
    qkv: &[f32],
    qoff: usize,
    kbase: usize,
    vbase: usize,
    stride: usize,
    li: usize,
    dh: usize,
    scale: f32,
    stile: &mut [f32],
    out: &mut [f32],
) {
    let base = qkv.as_ptr();
    let qp = base.add(qoff);
    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    out.fill(0.0);
    let optr = out.as_mut_ptr();
    let mut j0 = 0usize;
    while j0 < li {
        let tl = (j0 + ATTN_TILE).min(li) - j0;
        let mut t = 0usize;
        while t + 4 <= tl {
            let (s0, s1, s2, s3) = dot4(
                qp,
                base.add(kbase + (j0 + t) * stride),
                base.add(kbase + (j0 + t + 1) * stride),
                base.add(kbase + (j0 + t + 2) * stride),
                base.add(kbase + (j0 + t + 3) * stride),
                dh,
            );
            stile[t] = s0 * scale;
            stile[t + 1] = s1 * scale;
            stile[t + 2] = s2 * scale;
            stile[t + 3] = s3 * scale;
            t += 4;
        }
        while t < tl {
            stile[t] = dot1(qp, base.add(kbase + (j0 + t) * stride), dh) * scale;
            t += 1;
        }
        let mut mt = stile[0];
        for &sv in &stile[1..tl] {
            mt = mt.max(sv);
        }
        if mt > m {
            if m > f32::NEG_INFINITY {
                let r = exp_approx(m - mt);
                l *= r;
                let rv = _mm256_set1_ps(r);
                let mut u = 0usize;
                while u + 8 <= dh {
                    _mm256_storeu_ps(optr.add(u), _mm256_mul_ps(_mm256_loadu_ps(optr.add(u)), rv));
                    u += 8;
                }
                while u < dh {
                    *optr.add(u) *= r;
                    u += 1;
                }
            }
            m = mt;
        }
        // probabilities: vector exp over full 8-lane groups, exp_approx tail
        let mv = _mm256_set1_ps(m);
        let sp = stile.as_mut_ptr();
        let mut t = 0usize;
        while t + 8 <= tl {
            _mm256_storeu_ps(
                sp.add(t),
                exp_ps(_mm256_sub_ps(_mm256_loadu_ps(sp.add(t)), mv)),
            );
            t += 8;
        }
        while t < tl {
            stile[t] = exp_approx(stile[t] - m);
            t += 1;
        }
        for &p in &stile[..tl] {
            l += p;
        }
        for t in 0..tl {
            let vr = base.add(vbase + (j0 + t) * stride);
            let pv = _mm256_set1_ps(stile[t]);
            let mut u = 0usize;
            while u + 8 <= dh {
                _mm256_storeu_ps(
                    optr.add(u),
                    _mm256_fmadd_ps(pv, _mm256_loadu_ps(vr.add(u)), _mm256_loadu_ps(optr.add(u))),
                );
                u += 8;
            }
            while u < dh {
                *optr.add(u) = stile[t].mul_add(*vr.add(u), *optr.add(u));
                u += 1;
            }
        }
        j0 += ATTN_TILE;
    }
    let inv = 1.0 / l;
    let iv = _mm256_set1_ps(inv);
    let mut u = 0usize;
    while u + 8 <= dh {
        _mm256_storeu_ps(optr.add(u), _mm256_mul_ps(_mm256_loadu_ps(optr.add(u)), iv));
        u += 8;
    }
    while u < dh {
        *optr.add(u) *= inv;
        u += 1;
    }
}

// --------------------------------------------------- vectorized elementwise

/// Vectorized row-wise layer norm (eps 1e-5). Mean and variance reduce
/// through the deterministic `hsum_ps` with plain-add scalar tails; the
/// normalize step pairs a vector FMA with a `mul_add` tail so each element
/// rounds identically regardless of its position in the row.
///
/// # Safety
/// Caller must ensure AVX2+FMA support; `src.len()` and `dst.len()` must be
/// equal multiples of `d`, and `g`/`b` must each hold at least `d` floats.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn layer_norm_avx2(src: &[f32], g: &[f32], b: &[f32], dst: &mut [f32], d: usize) {
    let inv_d = 1.0 / d as f32;
    for (srow, drow) in src.chunks_exact(d).zip(dst.chunks_exact_mut(d)) {
        let sp = srow.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut p = 0usize;
        while p + 8 <= d {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(sp.add(p)));
            p += 8;
        }
        let mut sum = hsum_ps(acc);
        while p < d {
            sum += srow[p];
            p += 1;
        }
        let mean = sum * inv_d;
        let meanv = _mm256_set1_ps(mean);
        let mut vacc = _mm256_setzero_ps();
        p = 0;
        while p + 8 <= d {
            let c = _mm256_sub_ps(_mm256_loadu_ps(sp.add(p)), meanv);
            vacc = _mm256_fmadd_ps(c, c, vacc);
            p += 8;
        }
        let mut var = hsum_ps(vacc);
        while p < d {
            let c = srow[p] - mean;
            var = c.mul_add(c, var);
            p += 1;
        }
        let inv = 1.0 / (var * inv_d + 1e-5).sqrt();
        let invv = _mm256_set1_ps(inv);
        let dp = drow.as_mut_ptr();
        p = 0;
        while p + 8 <= d {
            let t = _mm256_mul_ps(_mm256_sub_ps(_mm256_loadu_ps(sp.add(p)), meanv), invv);
            let y = _mm256_fmadd_ps(
                t,
                _mm256_loadu_ps(g.as_ptr().add(p)),
                _mm256_loadu_ps(b.as_ptr().add(p)),
            );
            _mm256_storeu_ps(dp.add(p), y);
            p += 8;
        }
        while p < d {
            drow[p] = ((srow[p] - mean) * inv).mul_add(g[p], b[p]);
            p += 1;
        }
    }
}

/// `dst[i] += a[i] * b[i]` with FMA; the scalar tail uses `mul_add` so every
/// element rounds identically to a vector lane (the fused mux accumulate).
///
/// # Safety
/// Caller must ensure AVX2+FMA support and that `a` and `b` each hold at
/// least `dst.len()` floats.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn fmadd_buf_avx2(dst: &mut [f32], a: &[f32], b: &[f32]) {
    let n = dst.len();
    let (dp, ap, bp) = (dst.as_mut_ptr(), a.as_ptr(), b.as_ptr());
    let mut p = 0usize;
    while p + 8 <= n {
        let y = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(p)),
            _mm256_loadu_ps(bp.add(p)),
            _mm256_loadu_ps(dp.add(p)),
        );
        _mm256_storeu_ps(dp.add(p), y);
        p += 8;
    }
    while p < n {
        *dp.add(p) = (*ap.add(p)).mul_add(*bp.add(p), *dp.add(p));
        p += 1;
    }
}

/// Residual add `dst[i] += src[i]`. Pure elementwise addition, so the
/// vector body and scalar tail are bitwise identical by construction.
///
/// # Safety
/// Caller must ensure AVX2 support and `src.len() >= dst.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn add_assign_avx2(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let (dp, sp) = (dst.as_mut_ptr(), src.as_ptr());
    let mut p = 0usize;
    while p + 8 <= n {
        _mm256_storeu_ps(
            dp.add(p),
            _mm256_add_ps(_mm256_loadu_ps(dp.add(p)), _mm256_loadu_ps(sp.add(p))),
        );
        p += 8;
    }
    while p < n {
        *dp.add(p) += *sp.add(p);
        p += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_selection_is_cached_and_consistent() {
        let first = active_kernel();
        for _ in 0..4 {
            assert_eq!(active_kernel(), first);
        }
        assert!(!first.name().is_empty());
        assert_eq!(format!("{first}"), first.name());
    }

    #[cfg(target_arch = "x86_64")]
    fn has_avx2_fma() -> bool {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_f32_gemm_matches_scalar_kernel() {
        if !has_avx2_fma() {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(0x51AD);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (2, 9, 3), (5, 33, 66), (7, 64, 130)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            for with_bias in [false, true] {
                let b = if with_bias { Some(bias.as_slice()) } else { None };
                let mut want = vec![0.0f32; m * n];
                super::super::gemm::gemm_bt_scalar(&a, &bt, b, &mut want, m, k, n);
                let mut got = vec![0.0f32; m * n];
                unsafe { gemm_bt_f32_avx2(&a, &bt, b, &mut got, m, k, n) };
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "{g} vs {w} ({m},{k},{n})");
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_quantize_row_is_bitwise_identical_to_scalar() {
        if !has_avx2_fma() {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(0xA11A);
        for k in [1usize, 7, 8, 9, 31, 64, 130] {
            let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 3.0).collect();
            let mut qs = vec![0u8; k];
            let mut qv = vec![0u8; k];
            let ss = super::super::quant::quantize_row_scalar(&x, &mut qs);
            let sv = unsafe { quantize_row_avx2(&x, &mut qv) };
            assert_eq!(ss.to_bits(), sv.to_bits(), "scale mismatch at k={k}");
            assert_eq!(qs, qv, "codes mismatch at k={k}");
        }
        // all-zero row: both arms emit the bias code and a zero scale
        let zeros = vec![0.0f32; 13];
        let mut qs = vec![0u8; 13];
        let mut qv = vec![0u8; 13];
        assert_eq!(super::super::quant::quantize_row_scalar(&zeros, &mut qs), 0.0);
        assert_eq!(unsafe { quantize_row_avx2(&zeros, &mut qv) }, 0.0);
        assert_eq!(qs, qv);
        assert!(qs.iter().all(|&q| q == 128));
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_q8_gemm_is_bitwise_identical_to_scalar() {
        if !has_avx2_fma() {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(0x0808);
        for &(m, k, n) in &[(1usize, 3usize, 1usize), (2, 32, 5), (3, 37, 9), (4, 96, 70)] {
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let w = QuantMat::from_bt(&bt, n, k);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let mut aq = vec![0u8; m * k];
            let mut ascale = vec![0.0f32; m];
            for i in 0..m {
                ascale[i] =
                    super::super::quant::quantize_row_scalar(&a[i * k..(i + 1) * k], &mut aq[i * k..(i + 1) * k]);
            }
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            for with_bias in [false, true] {
                let b = if with_bias { Some(bias.as_slice()) } else { None };
                let mut want = vec![0.0f32; m * n];
                super::super::quant::gemm_bt_q8_scalar(&aq, &ascale, &w, b, &mut want, m, k, n);
                let mut got = vec![0.0f32; m * n];
                unsafe { gemm_bt_q8_avx2(&aq, &ascale, &w, b, &mut got, m, k, n) };
                assert_eq!(
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "q8 arms diverged at ({m},{k},{n})"
                );
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_gelu_matches_scalar_gelu() {
        if !has_avx2_fma() {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(0x6E1);
        for len in [1usize, 7, 8, 9, 40, 257] {
            let xs: Vec<f32> = (0..len).map(|_| rng.normal() as f32 * 4.0).collect();
            let mut got = xs.clone();
            unsafe { gelu_avx2(&mut got) };
            for (x, g) in xs.iter().zip(&got) {
                let want = gelu(*x);
                assert!(
                    (g - want).abs() <= 2e-5 * (1.0 + want.abs()),
                    "gelu({x}) = {g}, want {want}"
                );
            }
        }
    }

    /// Straightforward two-pass softmax attention over one query row —
    /// the oracle both flash arms are checked against.
    fn naive_attn_row(
        qkv: &[f32],
        qoff: usize,
        kbase: usize,
        vbase: usize,
        stride: usize,
        li: usize,
        dh: usize,
        scale: f32,
    ) -> Vec<f32> {
        let q = &qkv[qoff..qoff + dh];
        let mut scores: Vec<f32> = (0..li)
            .map(|j| {
                let k = &qkv[kbase + j * stride..][..dh];
                q.iter().zip(k).map(|(a, b)| a * b).sum::<f32>() * scale
            })
            .collect();
        let m = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut l = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            l += *s;
        }
        let mut out = vec![0.0f32; dh];
        for (j, p) in scores.iter().enumerate() {
            let v = &qkv[vbase + j * stride..][..dh];
            for u in 0..dh {
                out[u] += (p / l) * v[u];
            }
        }
        out
    }

    #[test]
    fn flash_attention_scalar_matches_naive_softmax() {
        let mut rng = crate::util::rng::Rng::new(0xF1A5);
        // li crossing the tile boundary both ways, dh crossing the 8-lane
        // vector width both ways
        for &(li, dh) in &[
            (1usize, 4usize),
            (2, 8),
            (5, 12),
            (15, 8),
            (16, 8),
            (17, 4),
            (33, 32),
            (48, 8),
        ] {
            let heads = 2usize;
            let d = heads * dh;
            let stride = 3 * d;
            let qkv: Vec<f32> = (0..li * stride).map(|_| rng.normal() as f32).collect();
            let scale = 1.0 / (dh as f32).sqrt();
            for hh in 0..heads {
                let kbase = d + hh * dh;
                let vbase = 2 * d + hh * dh;
                for i in 0..li {
                    let qoff = i * stride + hh * dh;
                    let want = naive_attn_row(&qkv, qoff, kbase, vbase, stride, li, dh, scale);
                    let mut stile = [0.0f32; ATTN_TILE];
                    let mut got = vec![0.0f32; dh];
                    flash_attn_row_scalar(
                        &qkv, qoff, kbase, vbase, stride, li, dh, scale, &mut stile, &mut got,
                    );
                    for (g, w) in got.iter().zip(&want) {
                        assert!(
                            (g - w).abs() <= 2e-5 * (1.0 + w.abs()),
                            "scalar flash {g} vs naive {w} (li={li}, dh={dh}, row={i})"
                        );
                    }
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn flash_attention_avx2_matches_scalar_arm() {
        if !has_avx2_fma() {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(0xF1A6);
        for &(li, dh) in &[
            (1usize, 4usize),
            (3, 8),
            (15, 12),
            (16, 16),
            (17, 8),
            (31, 4),
            (48, 32),
        ] {
            let heads = 2usize;
            let d = heads * dh;
            let stride = 3 * d;
            let qkv: Vec<f32> = (0..li * stride).map(|_| rng.normal() as f32).collect();
            let scale = 1.0 / (dh as f32).sqrt();
            for hh in 0..heads {
                let kbase = d + hh * dh;
                let vbase = 2 * d + hh * dh;
                for i in 0..li {
                    let qoff = i * stride + hh * dh;
                    let mut stile_s = [0.0f32; ATTN_TILE];
                    let mut want = vec![0.0f32; dh];
                    flash_attn_row_scalar(
                        &qkv, qoff, kbase, vbase, stride, li, dh, scale, &mut stile_s, &mut want,
                    );
                    let mut stile_v = [0.0f32; ATTN_TILE];
                    let mut got = vec![0.0f32; dh];
                    unsafe {
                        flash_attn_row_avx2(
                            &qkv, qoff, kbase, vbase, stride, li, dh, scale, &mut stile_v,
                            &mut got,
                        )
                    };
                    for (g, w) in got.iter().zip(&want) {
                        assert!(
                            (g - w).abs() <= 1e-5 * (1.0 + w.abs()),
                            "avx2 flash {g} vs scalar {w} (li={li}, dh={dh}, row={i})"
                        );
                    }
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn exp_approx_is_bitwise_identical_to_exp_ps_lanes() {
        if !has_avx2_fma() {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(0xE4B);
        let mut xs: Vec<f32> = vec![0.0, -0.5, 1.0, -90.0, 90.0, 88.376, -88.376, -13.7];
        xs.extend((0..64).map(|_| rng.normal() as f32 * 20.0));
        for chunk in xs.chunks(8) {
            let mut buf = [0.0f32; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            let mut got = [0.0f32; 8];
            unsafe {
                _mm256_storeu_ps(got.as_mut_ptr(), exp_ps(_mm256_loadu_ps(buf.as_ptr())));
            }
            for (x, g) in buf.iter().zip(&got) {
                assert_eq!(
                    exp_approx(*x).to_bits(),
                    g.to_bits(),
                    "exp_approx({x}) diverged from exp_ps lane"
                );
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_layer_norm_matches_plain_scalar_norm() {
        if !has_avx2_fma() {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(0x17A0);
        for &(rows, d) in &[(1usize, 4usize), (2, 8), (3, 13), (2, 64), (1, 130)] {
            let src: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
            let g: Vec<f32> = (0..d).map(|_| 1.0 + rng.normal() as f32 * 0.1).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
            let mut got = vec![0.0f32; rows * d];
            unsafe { layer_norm_avx2(&src, &g, &b, &mut got, d) };
            for r in 0..rows {
                let row = &src[r * d..(r + 1) * d];
                let mean = row.iter().sum::<f32>() / d as f32;
                let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + 1e-5).sqrt();
                for c in 0..d {
                    let want = (row[c] - mean) * inv * g[c] + b[c];
                    let gv = got[r * d + c];
                    assert!(
                        (gv - want).abs() <= 1e-4 * (1.0 + want.abs()),
                        "layer_norm[{r},{c}] = {gv}, want {want} (d={d})"
                    );
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_fmadd_and_residual_add_match_scalar() {
        if !has_avx2_fma() {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(0xADD);
        for n in [1usize, 7, 8, 9, 40, 130] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut got = base.clone();
            unsafe { fmadd_buf_avx2(&mut got, &a, &b) };
            for i in 0..n {
                let want = a[i].mul_add(b[i], base[i]);
                assert_eq!(got[i].to_bits(), want.to_bits(), "fmadd lane {i} (n={n})");
            }
            let mut got = base.clone();
            unsafe { add_assign_avx2(&mut got, &a) };
            for i in 0..n {
                assert_eq!(got[i].to_bits(), (base[i] + a[i]).to_bits(), "add lane {i}");
            }
        }
    }
}
