//! Weight blob loader.
//!
//! Format written by `python/compile/aot.py::write_weights`:
//!
//! ```text
//! b"DMUXW1\n"  |  u32 header_len (LE)  |  json header  |  raw f32 data
//! ```
//!
//! The header lists tensors **in the jax pytree flatten order**, which is
//! exactly the parameter order of the lowered HLO — the runtime uploads
//! them in this order and appends the ids input last.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

const MAGIC: &[u8] = b"DMUXW1\n";

#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

#[derive(Debug)]
pub struct WeightsFile {
    pub tensors: Vec<TensorMeta>,
    data: Vec<u8>,
}

impl WeightsFile {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(bytes)
    }

    pub fn parse(mut bytes: Vec<u8>) -> Result<Self> {
        if bytes.len() < MAGIC.len() + 4 || &bytes[..MAGIC.len()] != MAGIC {
            bail!("not a DMUXW1 weights file");
        }
        let hl_off = MAGIC.len();
        let header_len =
            u32::from_le_bytes(bytes[hl_off..hl_off + 4].try_into().unwrap()) as usize;
        let hdr_start = hl_off + 4;
        let data_start = hdr_start + header_len;
        if bytes.len() < data_start {
            bail!("truncated weights header");
        }
        let header = std::str::from_utf8(&bytes[hdr_start..data_start])
            .context("weights header not utf-8")?;
        let json = Json::parse(header).map_err(|e| anyhow!("weights header: {e}"))?;
        let mut tensors = Vec::new();
        for t in json
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("weights header missing tensors"))?
        {
            let shape: Vec<usize> = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let dtype = t.get("dtype").and_then(Json::as_str).unwrap_or("f32");
            if dtype != "f32" {
                bail!("unsupported tensor dtype {dtype}");
            }
            let meta = TensorMeta {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("tensor missing name"))?
                    .to_string(),
                shape,
                offset: t
                    .get("offset")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("tensor missing offset"))?,
                nbytes: t
                    .get("nbytes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("tensor missing nbytes"))?,
            };
            let elems: usize = meta.shape.iter().product::<usize>().max(1);
            if elems * 4 != meta.nbytes {
                bail!("tensor {} shape/nbytes mismatch", meta.name);
            }
            tensors.push(meta);
        }
        // Split the blob in place: drain the magic+header prefix so the
        // incoming allocation *becomes* the tensor data. The previous
        // `bytes[data_start..].to_vec()` held the full file plus a copy of
        // the data section alive at once — 2x peak RSS on load.
        bytes.drain(..data_start);
        let data = bytes;
        let total: usize = tensors.iter().map(|t| t.nbytes).sum();
        if data.len() != total {
            bail!("weights data length {} != header total {}", data.len(), total);
        }
        for t in &tensors {
            if t.offset % 4 != 0 || t.offset + t.nbytes > data.len() {
                bail!(
                    "tensor {} range {}..{} invalid for data length {}",
                    t.name,
                    t.offset,
                    t.offset + t.nbytes,
                    data.len()
                );
            }
        }
        Ok(WeightsFile { tensors, data })
    }

    /// Owned f32 copy of one tensor's data.
    pub fn tensor_f32(&self, idx: usize) -> Result<Vec<f32>> {
        let t = self.tensors.get(idx).ok_or_else(|| anyhow!("tensor index {idx} oob"))?;
        let raw = &self.data[t.offset..t.offset + t.nbytes];
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Zero-copy f32 view of one tensor's data — the native backend
    /// borrows its gather tables (embeddings) straight out of the blob
    /// instead of cloning them.
    ///
    /// Assumes a little-endian host (the on-disk format is LE; every
    /// supported target is). Errs on the pathological case of a
    /// 4-unaligned allocation, where callers must fall back to
    /// [`tensor_f32`](Self::tensor_f32).
    pub fn tensor_f32_view(&self, idx: usize) -> Result<&[f32]> {
        let t = self.tensors.get(idx).ok_or_else(|| anyhow!("tensor index {idx} oob"))?;
        let raw = &self.data[t.offset..t.offset + t.nbytes];
        // SAFETY: every f32 bit pattern is valid; align_to hands back
        // non-empty prefix/suffix only when the allocation is unaligned,
        // which we reject below instead of mis-reading.
        let (pre, mid, post) = unsafe { raw.align_to::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            bail!("weights allocation is not 4-byte aligned; use tensor_f32");
        }
        Ok(mid)
    }

    pub fn total_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn param_count(&self) -> usize {
        self.data.len() / 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_file() -> Vec<u8> {
        let header = br#"{"tensors": [
            {"name": "a", "shape": [2, 2], "dtype": "f32", "offset": 0, "nbytes": 16},
            {"name": "b", "shape": [3], "dtype": "f32", "offset": 16, "nbytes": 12}
        ]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header);
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes
    }

    #[test]
    fn parses_and_reads_tensors() {
        let w = WeightsFile::parse(sample_file()).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.tensors[0].shape, vec![2, 2]);
        assert_eq!(w.tensor_f32(0).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.tensor_f32(1).unwrap(), vec![5.0, 6.0, 7.0]);
        assert_eq!(w.param_count(), 7);
    }

    #[test]
    fn zero_copy_view_matches_copying_reader() {
        let w = WeightsFile::parse(sample_file()).unwrap();
        for i in 0..w.tensors.len() {
            assert_eq!(w.tensor_f32_view(i).unwrap(), w.tensor_f32(i).unwrap().as_slice());
        }
        assert!(w.tensor_f32_view(9).is_err());
    }

    #[test]
    fn rejects_out_of_range_tensor_offsets() {
        let header = br#"{"tensors": [
            {"name": "a", "shape": [2, 2], "dtype": "f32", "offset": 8, "nbytes": 16}
        ]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header);
        bytes.extend_from_slice(&[0u8; 16]);
        // total nbytes matches data length, but offset 8 + 16 runs past it
        assert!(WeightsFile::parse(bytes).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample_file();
        b[0] = b'X';
        assert!(WeightsFile::parse(b).is_err());
    }

    #[test]
    fn rejects_truncated_data() {
        let mut b = sample_file();
        b.truncate(b.len() - 4);
        assert!(WeightsFile::parse(b).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let header = br#"{"tensors": [
            {"name": "a", "shape": [2, 3], "dtype": "f32", "offset": 0, "nbytes": 16}
        ]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header);
        bytes.extend_from_slice(&[0u8; 16]);
        assert!(WeightsFile::parse(bytes).is_err());
    }
}
