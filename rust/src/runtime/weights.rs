//! Weight blob loader.
//!
//! Format written by `python/compile/aot.py::write_weights` (v1) and
//! `RawWeights::to_blob{,_q8}` (v1/v2):
//!
//! ```text
//! b"DMUXW1\n"  |  u32 header_len (LE)  |  json header  |  raw f32 data
//! b"DMUXW2\n"  |  u32 header_len (LE)  |  json header  |  mixed data
//! ```
//!
//! The header lists tensors **in the jax pytree flatten order**, which is
//! exactly the parameter order of the lowered HLO — the runtime uploads
//! them in this order and appends the ids input last.
//!
//! `DMUXW2` extends v1 with per-tensor `dtype` of `"i8"`: the payload is
//! int8 codes (still in the tensor's row-major shape order), and the
//! entry carries `scales_offset`/`scales_nbytes` pointing at f32
//! per-output-channel scales (one per column of the 2-D tensor, since
//! the blob layout is `(in, out)`). int8 regions are padded to 4-byte
//! alignment before the next f32 region. `DMUXW1` files — all-f32, no
//! padding — parse exactly as before.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

const MAGIC_V1: &[u8] = b"DMUXW1\n";
const MAGIC_V2: &[u8] = b"DMUXW2\n";

/// On-disk element type of one tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I8,
}

impl Dtype {
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::I8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I8 => "i8",
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
    pub dtype: Dtype,
    /// Byte offset of the f32 per-output-channel scales (i8 tensors only).
    pub scales_offset: usize,
    pub scales_nbytes: usize,
}

#[derive(Debug)]
pub struct WeightsFile {
    pub tensors: Vec<TensorMeta>,
    data: Vec<u8>,
}

impl WeightsFile {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(bytes)
    }

    pub fn parse(mut bytes: Vec<u8>) -> Result<Self> {
        if bytes.len() < MAGIC_V1.len() + 4 {
            bail!("not a DMUXW1/DMUXW2 weights file");
        }
        let v2 = match &bytes[..MAGIC_V1.len()] {
            m if m == MAGIC_V1 => false,
            m if m == MAGIC_V2 => true,
            _ => bail!("not a DMUXW1/DMUXW2 weights file"),
        };
        let hl_off = MAGIC_V1.len();
        let header_len =
            u32::from_le_bytes(bytes[hl_off..hl_off + 4].try_into().unwrap()) as usize;
        let hdr_start = hl_off + 4;
        let data_start = hdr_start + header_len;
        if bytes.len() < data_start {
            bail!("truncated weights header");
        }
        let header = std::str::from_utf8(&bytes[hdr_start..data_start])
            .context("weights header not utf-8")?;
        let json = Json::parse(header).map_err(|e| anyhow!("weights header: {e}"))?;
        let mut tensors = Vec::new();
        for t in json
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("weights header missing tensors"))?
        {
            let shape: Vec<usize> = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let dtype = match t.get("dtype").and_then(Json::as_str).unwrap_or("f32") {
                "f32" => Dtype::F32,
                "i8" if v2 => Dtype::I8,
                "i8" => bail!("int8 tensors require the DMUXW2 format revision"),
                other => bail!("unsupported tensor dtype {other}"),
            };
            let meta = TensorMeta {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("tensor missing name"))?
                    .to_string(),
                shape,
                offset: t
                    .get("offset")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("tensor missing offset"))?,
                nbytes: t
                    .get("nbytes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("tensor missing nbytes"))?,
                dtype,
                scales_offset: t.get("scales_offset").and_then(Json::as_usize).unwrap_or(0),
                scales_nbytes: t.get("scales_nbytes").and_then(Json::as_usize).unwrap_or(0),
            };
            let elems: usize = meta.shape.iter().product::<usize>().max(1);
            if elems * meta.dtype.bytes() != meta.nbytes {
                bail!("tensor {} shape/nbytes mismatch", meta.name);
            }
            if meta.dtype == Dtype::I8 {
                if meta.shape.len() != 2 {
                    bail!("int8 tensor {} must be 2-D (got {:?})", meta.name, meta.shape);
                }
                if meta.scales_nbytes != meta.shape[1] * 4 {
                    bail!(
                        "int8 tensor {} needs {} scale bytes (one f32 per output \
                         channel), header says {}",
                        meta.name,
                        meta.shape[1] * 4,
                        meta.scales_nbytes
                    );
                }
            }
            tensors.push(meta);
        }
        // Split the blob in place: drain the magic+header prefix so the
        // incoming allocation *becomes* the tensor data. The previous
        // `bytes[data_start..].to_vec()` held the full file plus a copy of
        // the data section alive at once — 2x peak RSS on load.
        bytes.drain(..data_start);
        let data = bytes;
        if v2 {
            // v2 interleaves i8 payloads, alignment padding, and scale
            // arrays, so the sum-of-nbytes invariant no longer holds;
            // instead require the data section to end exactly at (or
            // within one padding word of) the furthest declared region.
            let max_end = tensors
                .iter()
                .flat_map(|t| {
                    [t.offset + t.nbytes, t.scales_offset + t.scales_nbytes]
                })
                .max()
                .unwrap_or(0);
            if data.len() < max_end || data.len() - max_end >= 4 {
                bail!("weights data length {} inconsistent with header end {}", data.len(), max_end);
            }
        } else {
            let total: usize = tensors.iter().map(|t| t.nbytes).sum();
            if data.len() != total {
                bail!("weights data length {} != header total {}", data.len(), total);
            }
        }
        for t in &tensors {
            let aligned = t.dtype != Dtype::F32 || t.offset % 4 == 0;
            if !aligned || t.offset + t.nbytes > data.len() {
                bail!(
                    "tensor {} range {}..{} invalid for data length {}",
                    t.name,
                    t.offset,
                    t.offset + t.nbytes,
                    data.len()
                );
            }
            if t.dtype == Dtype::I8
                && (t.scales_offset % 4 != 0 || t.scales_offset + t.scales_nbytes > data.len())
            {
                bail!(
                    "tensor {} scales range {}..{} invalid for data length {}",
                    t.name,
                    t.scales_offset,
                    t.scales_offset + t.scales_nbytes,
                    data.len()
                );
            }
        }
        Ok(WeightsFile { tensors, data })
    }

    /// Owned f32 copy of one tensor's data.
    pub fn tensor_f32(&self, idx: usize) -> Result<Vec<f32>> {
        let t = self.tensors.get(idx).ok_or_else(|| anyhow!("tensor index {idx} oob"))?;
        if t.dtype != Dtype::F32 {
            bail!("tensor {} is {}, not f32", t.name, t.dtype.name());
        }
        let raw = &self.data[t.offset..t.offset + t.nbytes];
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Zero-copy f32 view of one tensor's data — the native backend
    /// borrows its gather tables (embeddings) straight out of the blob
    /// instead of cloning them.
    ///
    /// Assumes a little-endian host (the on-disk format is LE; every
    /// supported target is). Errs on the pathological case of a
    /// 4-unaligned allocation, where callers must fall back to
    /// [`tensor_f32`](Self::tensor_f32).
    pub fn tensor_f32_view(&self, idx: usize) -> Result<&[f32]> {
        let t = self.tensors.get(idx).ok_or_else(|| anyhow!("tensor index {idx} oob"))?;
        if t.dtype != Dtype::F32 {
            bail!("tensor {} is {}, not f32", t.name, t.dtype.name());
        }
        let raw = &self.data[t.offset..t.offset + t.nbytes];
        // SAFETY: every f32 bit pattern is valid; align_to hands back
        // non-empty prefix/suffix only when the allocation is unaligned,
        // which we reject below instead of mis-reading.
        let (pre, mid, post) = unsafe { raw.align_to::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            bail!("weights allocation is not 4-byte aligned; use tensor_f32");
        }
        Ok(mid)
    }

    /// Zero-copy int8 view of a `DMUXW2` quantized tensor's codes.
    pub fn tensor_i8_view(&self, idx: usize) -> Result<&[i8]> {
        let t = self.tensors.get(idx).ok_or_else(|| anyhow!("tensor index {idx} oob"))?;
        if t.dtype != Dtype::I8 {
            bail!("tensor {} is {}, not i8", t.name, t.dtype.name());
        }
        let raw = &self.data[t.offset..t.offset + t.nbytes];
        // SAFETY: i8 and u8 have identical layout and every bit pattern
        // is valid; the range was validated at parse time.
        Ok(unsafe { std::slice::from_raw_parts(raw.as_ptr() as *const i8, raw.len()) })
    }

    /// The per-output-channel f32 scales of a quantized tensor.
    pub fn tensor_scales(&self, idx: usize) -> Result<&[f32]> {
        let t = self.tensors.get(idx).ok_or_else(|| anyhow!("tensor index {idx} oob"))?;
        if t.dtype != Dtype::I8 {
            bail!("tensor {} is {}, has no scales", t.name, t.dtype.name());
        }
        let raw = &self.data[t.scales_offset..t.scales_offset + t.scales_nbytes];
        // SAFETY: as in tensor_f32_view.
        let (pre, mid, post) = unsafe { raw.align_to::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            bail!("weights allocation is not 4-byte aligned");
        }
        Ok(mid)
    }

    pub fn total_bytes(&self) -> usize {
        self.data.len()
    }

    /// Logical parameter count (independent of on-disk precision).
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.shape.iter().product::<usize>().max(1)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_file() -> Vec<u8> {
        let header = br#"{"tensors": [
            {"name": "a", "shape": [2, 2], "dtype": "f32", "offset": 0, "nbytes": 16},
            {"name": "b", "shape": [3], "dtype": "f32", "offset": 16, "nbytes": 12}
        ]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header);
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes
    }

    /// v2 file: one (2, 3) int8 tensor (+ padding + 3 scales), then a
    /// (2,) f32 tensor.
    fn sample_file_v2() -> Vec<u8> {
        let header = br#"{"tensors": [
            {"name": "q", "shape": [2, 3], "dtype": "i8", "offset": 0, "nbytes": 6,
             "scales_offset": 8, "scales_nbytes": 12},
            {"name": "b", "shape": [2], "dtype": "f32", "offset": 20, "nbytes": 8}
        ]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header);
        bytes.extend_from_slice(&[1i8 as u8, 2, 3, (-4i8) as u8, 5, 63]); // codes
        bytes.extend_from_slice(&[0u8; 2]); // pad to 4
        for s in [0.5f32, 0.25, 2.0] {
            bytes.extend_from_slice(&s.to_le_bytes());
        }
        for v in [9.0f32, 10.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes
    }

    #[test]
    fn parses_and_reads_tensors() {
        let w = WeightsFile::parse(sample_file()).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.tensors[0].shape, vec![2, 2]);
        assert_eq!(w.tensors[0].dtype, Dtype::F32);
        assert_eq!(w.tensor_f32(0).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.tensor_f32(1).unwrap(), vec![5.0, 6.0, 7.0]);
        assert_eq!(w.param_count(), 7);
    }

    #[test]
    fn zero_copy_view_matches_copying_reader() {
        let w = WeightsFile::parse(sample_file()).unwrap();
        for i in 0..w.tensors.len() {
            assert_eq!(w.tensor_f32_view(i).unwrap(), w.tensor_f32(i).unwrap().as_slice());
        }
        assert!(w.tensor_f32_view(9).is_err());
    }

    #[test]
    fn parses_v2_int8_tensors_with_scales() {
        let w = WeightsFile::parse(sample_file_v2()).unwrap();
        assert_eq!(w.tensors[0].dtype, Dtype::I8);
        assert_eq!(w.tensor_i8_view(0).unwrap(), &[1, 2, 3, -4, 5, 63]);
        assert_eq!(w.tensor_scales(0).unwrap(), &[0.5, 0.25, 2.0]);
        assert_eq!(w.tensor_f32(1).unwrap(), vec![9.0, 10.0]);
        // logical param count ignores precision: 6 + 2
        assert_eq!(w.param_count(), 8);
        // dtype-mismatched accessors refuse rather than mis-read
        assert!(w.tensor_f32(0).is_err());
        assert!(w.tensor_f32_view(0).is_err());
        assert!(w.tensor_i8_view(1).is_err());
        assert!(w.tensor_scales(1).is_err());
    }

    #[test]
    fn rejects_int8_under_v1_magic() {
        let mut bytes = sample_file_v2();
        bytes[..MAGIC_V1.len()].copy_from_slice(MAGIC_V1);
        let err = WeightsFile::parse(bytes).unwrap_err().to_string();
        assert!(err.contains("DMUXW2"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_v2_scales_out_of_range() {
        let header = br#"{"tensors": [
            {"name": "q", "shape": [2, 3], "dtype": "i8", "offset": 0, "nbytes": 6,
             "scales_offset": 8, "scales_nbytes": 12}
        ]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header);
        bytes.extend_from_slice(&[0u8; 8]); // codes + pad, but no scales
        assert!(WeightsFile::parse(bytes).is_err());
    }

    #[test]
    fn rejects_out_of_range_tensor_offsets() {
        let header = br#"{"tensors": [
            {"name": "a", "shape": [2, 2], "dtype": "f32", "offset": 8, "nbytes": 16}
        ]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header);
        bytes.extend_from_slice(&[0u8; 16]);
        // total nbytes matches data length, but offset 8 + 16 runs past it
        assert!(WeightsFile::parse(bytes).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample_file();
        b[0] = b'X';
        assert!(WeightsFile::parse(b).is_err());
    }

    #[test]
    fn rejects_truncated_data() {
        let mut b = sample_file();
        b.truncate(b.len() - 4);
        assert!(WeightsFile::parse(b).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let header = br#"{"tensors": [
            {"name": "a", "shape": [2, 3], "dtype": "f32", "offset": 0, "nbytes": 16}
        ]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header);
        bytes.extend_from_slice(&[0u8; 16]);
        assert!(WeightsFile::parse(bytes).is_err());
    }
}
