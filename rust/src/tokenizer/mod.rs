//! Tokenizer: the rust mirror of the synthetic vocabulary defined in
//! `python/compile/config.py` / `data.py::ids_to_text`.
//!
//! Word forms: `t{k}` content tokens, bracketed specials (`[CLS]`,
//! `[SEP]`, `[EPS]`, `[IDX{i}]`, `[PAD]`). `encode_framed` produces the
//! `[CLS] ... [SEP] ... [PAD]` layout the models were trained on;
//! `with_prefix` prepends the slot-index prefix (paper §3.2) used by the
//! index-embedding demultiplexer. Both sides pin the constants in tests.

use crate::runtime::VocabLayout;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab: VocabLayout,
    /// content vocabulary size (t0 .. t{n-1})
    pub n_content: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub enum TokenizeError {
    UnknownWord(String),
    ContentIdOutOfRange(usize),
    /// The framed row (`[CLS]` + content + separators) does not fit in
    /// `max` positions. Returned instead of silently truncating — a
    /// truncated tail used to corrupt the end of the sentence.
    TooLong { got: usize, max: usize },
}

impl std::fmt::Display for TokenizeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TokenizeError::UnknownWord(w) => write!(f, "unknown word '{w}'"),
            TokenizeError::ContentIdOutOfRange(k) => write!(f, "content id t{k} out of range"),
            TokenizeError::TooLong { got, max } => {
                write!(f, "framed input is {got} tokens, max is {max}")
            }
        }
    }
}

impl std::error::Error for TokenizeError {}

impl Tokenizer {
    pub fn new(vocab: VocabLayout, vocab_size: usize) -> Self {
        let n_content = vocab_size - vocab.content_base as usize;
        Tokenizer { vocab, n_content }
    }

    /// One word -> id.
    pub fn token_id(&self, word: &str) -> Result<i32, TokenizeError> {
        match word {
            "[PAD]" => Ok(self.vocab.pad),
            "[CLS]" => Ok(self.vocab.cls),
            "[SEP]" => Ok(self.vocab.sep),
            "[EPS]" => Ok(self.vocab.eps_pad),
            w => {
                if let Some(i) = w.strip_prefix("[IDX").and_then(|r| r.strip_suffix(']')) {
                    let i: usize = i
                        .parse()
                        .map_err(|_| TokenizeError::UnknownWord(w.to_string()))?;
                    if i >= self.vocab.max_mux {
                        return Err(TokenizeError::ContentIdOutOfRange(i));
                    }
                    return Ok(self.vocab.idx_base + i as i32);
                }
                if let Some(k) = w.strip_prefix('t') {
                    let k: usize = k
                        .parse()
                        .map_err(|_| TokenizeError::UnknownWord(w.to_string()))?;
                    if k >= self.n_content {
                        return Err(TokenizeError::ContentIdOutOfRange(k));
                    }
                    return Ok(self.vocab.content_base + k as i32);
                }
                Err(TokenizeError::UnknownWord(w.to_string()))
            }
        }
    }

    /// Whitespace-split tokenize.
    pub fn encode(&self, text: &str) -> Result<Vec<i32>, TokenizeError> {
        text.split_whitespace().map(|w| self.token_id(w)).collect()
    }

    /// `[CLS] part0... [SEP] part1... [SEP]` padded to exactly `seq_len`
    /// — the frame `python/compile/data.py::_frame` produces. Inputs
    /// that do not fit are rejected with [`TokenizeError::TooLong`]
    /// (never silently truncated: a clipped tail corrupts the sentence).
    pub fn encode_framed(&self, parts: &[&str], seq_len: usize) -> Result<Vec<i32>, TokenizeError> {
        let mut row = self.encode_framed_unpadded(parts, seq_len)?;
        row.resize(seq_len, self.vocab.pad);
        Ok(row)
    }

    /// The framed row **without padding**: `[CLS] part0... [SEP] ...`,
    /// validated to fit in `max_len` positions. This is the bucketed
    /// submission form — the engine pads to the request's sequence-length
    /// bucket at batch assembly, not here.
    pub fn encode_framed_unpadded(
        &self,
        parts: &[&str],
        max_len: usize,
    ) -> Result<Vec<i32>, TokenizeError> {
        let mut row = Vec::with_capacity(max_len.min(64));
        row.push(self.vocab.cls);
        for p in parts {
            row.extend(self.encode(p)?);
            row.push(self.vocab.sep);
        }
        if row.len() > max_len {
            return Err(TokenizeError::TooLong { got: row.len(), max: max_len });
        }
        Ok(row)
    }

    /// prefix^i = [EPS]*i + [IDX_i] + [EPS]*(n-1-i) (paper §3.2).
    pub fn prefix(&self, slot: usize, n_mux: usize) -> Vec<i32> {
        assert!(slot < n_mux && n_mux <= self.vocab.max_mux);
        let mut p = vec![self.vocab.eps_pad; n_mux];
        p[slot] = self.vocab.idx_base + slot as i32;
        p
    }

    /// Full model input row for one slot: prefix ++ framed content.
    pub fn with_prefix(&self, slot: usize, n_mux: usize, framed: &[i32]) -> Vec<i32> {
        let mut row = self.prefix(slot, n_mux);
        row.extend_from_slice(framed);
        row
    }

    /// An all-padding content row (used to fill empty mux slots).
    pub fn pad_row(&self, seq_len: usize) -> Vec<i32> {
        let mut row = vec![self.vocab.pad; seq_len];
        row[0] = self.vocab.cls; // keep the CLS anchor so demux stays in-distribution
        row
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut words = Vec::with_capacity(ids.len());
        for &t in ids {
            if t == self.vocab.pad {
                continue;
            }
            words.push(if t == self.vocab.cls {
                "[CLS]".to_string()
            } else if t == self.vocab.sep {
                "[SEP]".to_string()
            } else if t == self.vocab.eps_pad {
                "[EPS]".to_string()
            } else if t >= self.vocab.idx_base && t < self.vocab.content_base {
                format!("[IDX{}]", t - self.vocab.idx_base)
            } else {
                format!("t{}", t - self.vocab.content_base)
            });
        }
        words.join(" ")
    }
}

/// The canonical vocabulary layout (mirrors python/compile/config.py).
pub fn default_vocab() -> VocabLayout {
    VocabLayout {
        pad: 0,
        cls: 1,
        sep: 2,
        eps_pad: 3,
        idx_base: 4,
        max_mux: 40,
        content_base: 44,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(default_vocab(), 300)
    }

    #[test]
    fn pins_vocab_constants_to_python() {
        // mirrors python/compile/config.py — change both or neither
        let v = default_vocab();
        assert_eq!((v.pad, v.cls, v.sep, v.eps_pad), (0, 1, 2, 3));
        assert_eq!(v.idx_base, 4);
        assert_eq!(v.content_base, 44);
        assert_eq!(v.max_mux, 40);
    }

    #[test]
    fn encodes_content_and_specials() {
        let t = tok();
        assert_eq!(t.token_id("t0").unwrap(), 44);
        assert_eq!(t.token_id("t255").unwrap(), 299);
        assert_eq!(t.token_id("[CLS]").unwrap(), 1);
        assert_eq!(t.token_id("[IDX7]").unwrap(), 11);
        assert!(t.token_id("t256").is_err());
        assert!(t.token_id("hello").is_err());
        assert!(t.token_id("[IDX40]").is_err());
    }

    #[test]
    fn framed_layout_matches_python_frame() {
        let t = tok();
        let row = t.encode_framed(&["t1 t2", "t3"], 10).unwrap();
        assert_eq!(row, vec![1, 45, 46, 2, 47, 2, 0, 0, 0, 0]);
    }

    #[test]
    fn framed_rejects_long_input_instead_of_truncating() {
        let t = tok();
        let long = (0..20).map(|i| format!("t{i}")).collect::<Vec<_>>().join(" ");
        // 20 content tokens + [CLS] + [SEP] = 22 > 8: typed error, not a
        // silently clipped tail
        match t.encode_framed(&[&long], 8) {
            Err(TokenizeError::TooLong { got, max }) => {
                assert_eq!((got, max), (22, 8));
            }
            other => panic!("expected TooLong, got {other:?}"),
        }
        // exactly at the limit still fits
        let six = (0..6).map(|i| format!("t{i}")).collect::<Vec<_>>().join(" ");
        let row = t.encode_framed(&[&six], 8).unwrap();
        assert_eq!(row.len(), 8);
        assert_eq!(row[0], 1);
        assert_eq!(row[7], 2, "no padding needed at the exact fit");
    }

    #[test]
    fn unpadded_frame_has_no_padding_and_validates_length() {
        let t = tok();
        let row = t.encode_framed_unpadded(&["t1 t2", "t3"], 10).unwrap();
        assert_eq!(row, vec![1, 45, 46, 2, 47, 2], "no trailing [PAD]s");
        // the padded form is the unpadded form plus [PAD] fill
        let padded = t.encode_framed(&["t1 t2", "t3"], 10).unwrap();
        assert_eq!(&padded[..row.len()], &row[..]);
        assert!(padded[row.len()..].iter().all(|&x| x == 0));
        assert!(matches!(
            t.encode_framed_unpadded(&["t1 t2 t3 t4"], 4),
            Err(TokenizeError::TooLong { got: 6, max: 4 })
        ));
    }

    #[test]
    fn prefix_shape_matches_paper() {
        let t = tok();
        assert_eq!(t.prefix(0, 4), vec![4, 3, 3, 3]);
        assert_eq!(t.prefix(2, 4), vec![3, 3, 6, 3]);
        let row = t.with_prefix(1, 3, &[1, 50, 0]);
        assert_eq!(row, vec![3, 5, 3, 1, 50, 0]);
    }

    #[test]
    fn decode_roundtrips() {
        let t = tok();
        let text = "[CLS] t5 t6 [SEP]";
        let ids = t.encode(text).unwrap();
        assert_eq!(t.decode(&ids), text);
    }
}
