//! Seeded-jitter exponential backoff for reconnect/retry loops.
//!
//! Deterministic by construction: the jitter stream comes from a tiny
//! seeded LCG, so a breaker driven by a fixed `DATAMUX_FAULT_SEED` run
//! reproduces the exact same retry schedule in CI. The delay for attempt
//! `k` is `min(cap, base * 2^k)` scaled by a jitter factor in
//! `[0.5, 1.0)` — full-jitter-style decorrelation so a fleet of shards
//! opened by one event does not thundering-herd their half-open probes.

use std::time::Duration;

/// Multiplier applied to the LCG state before taking the high bits —
/// Knuth's MMIX constants, the same family the fault injector uses.
const LCG_MUL: u64 = 6364136223846793005;
const LCG_ADD: u64 = 1442695040888963407;

#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    /// consecutive failures since the last reset
    attempt: u32,
    state: u64,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Self {
        Backoff { base, cap, attempt: 0, state: seed.wrapping_mul(LCG_MUL).wrapping_add(LCG_ADD) }
    }

    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(LCG_MUL).wrapping_add(LCG_ADD);
        self.state >> 11
    }

    /// Jitter factor in `[0.5, 1.0)`.
    fn jitter(&mut self) -> f64 {
        0.5 + 0.5 * (self.next_u64() as f64 / (1u64 << 53) as f64)
    }

    /// Delay before the next retry; each call counts one more failure.
    /// Grows `base * 2^k`, saturates at `cap` (pre-jitter), never zero.
    pub fn next_delay(&mut self) -> Duration {
        let exp = self.attempt.min(32);
        self.attempt = self.attempt.saturating_add(1);
        let un_jittered = self
            .base
            .saturating_mul(1u32.checked_shl(exp).unwrap_or(u32::MAX))
            .min(self.cap);
        un_jittered.mul_f64(self.jitter()).max(Duration::from_millis(1))
    }

    /// Success: the next failure starts from `base` again.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    pub fn attempts(&self) -> u32 {
        self.attempt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_exponentially_and_caps() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(200);
        let mut b = Backoff::new(base, cap, 42);
        let delays: Vec<Duration> = (0..10).map(|_| b.next_delay()).collect();
        // jitter is in [0.5, 1.0): delay k is within [0.5, 1.0) * min(cap, base * 2^k)
        for (k, d) in delays.iter().enumerate() {
            let nominal = base.saturating_mul(1 << k.min(20)).min(cap);
            assert!(*d < nominal || nominal <= Duration::from_millis(1), "attempt {k}: {d:?}");
            assert!(*d >= nominal.mul_f64(0.5).min(cap), "attempt {k}: {d:?} vs {nominal:?}");
            assert!(*d <= cap, "cap must bound every delay: attempt {k} gave {d:?}");
        }
        // far attempts all sit at the (jittered) cap
        assert!(delays[9] >= cap.mul_f64(0.5));
    }

    #[test]
    fn reset_restarts_from_base() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 7);
        for _ in 0..6 {
            b.next_delay();
        }
        assert_eq!(b.attempts(), 6);
        b.reset();
        assert_eq!(b.attempts(), 0);
        assert!(b.next_delay() <= Duration::from_millis(10), "first delay after reset is base");
    }

    #[test]
    fn same_seed_same_schedule() {
        let mk = |seed| {
            let mut b = Backoff::new(Duration::from_millis(5), Duration::from_secs(2), seed);
            (0..8).map(|_| b.next_delay()).collect::<Vec<_>>()
        };
        assert_eq!(mk(123), mk(123), "deterministic for a fixed seed");
        assert_ne!(mk(123), mk(124), "different seeds decorrelate");
    }

    #[test]
    fn huge_attempt_counts_do_not_overflow() {
        let mut b = Backoff::new(Duration::from_secs(1), Duration::from_secs(30), 1);
        for _ in 0..100 {
            let d = b.next_delay();
            assert!(d <= Duration::from_secs(30));
            assert!(d >= Duration::from_millis(1));
        }
    }
}
