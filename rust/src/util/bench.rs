//! Micro/macro benchmark harness (criterion stand-in).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, timed iterations with outlier-robust statistics, and an
//! aligned-table printer whose rows mirror the paper's figures. Results
//! are also appended to `results/bench_*.json` so EXPERIMENTS.md can cite
//! exact numbers.

use std::time::{Duration, Instant};

use super::json::{self, Json};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl BenchStats {
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Benchmark one closure: `warmup` untimed runs, then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    stats_from(name, &mut samples)
}

/// Benchmark with a time budget instead of a fixed iteration count.
pub fn bench_for<F: FnMut()>(name: &str, warmup: usize, budget: Duration, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.is_empty() {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    stats_from(name, &mut samples)
}

fn stats_from(name: &str, samples: &mut [Duration]) -> BenchStats {
    samples.sort();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    let mean = sum / n as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|s| {
            let d = s.as_secs_f64() - mean_s;
            d * d
        })
        .sum::<f64>()
        / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean,
        median: samples[n / 2],
        min: samples[0],
        max: samples[n - 1],
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

// ---------------------------------------------------------------------------
// table printer
// ---------------------------------------------------------------------------

/// Fixed-width table, printed as the bench's figure-shaped output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

// ---------------------------------------------------------------------------
// results file
// ---------------------------------------------------------------------------

/// Write a bench result JSON under results/ (created on demand).
pub fn write_results(file: &str, value: Json) -> std::io::Result<()> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(file), value.to_pretty())
}

pub fn result_entry(stats: &BenchStats, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("name", json::s(&stats.name)),
        ("iters", json::num(stats.iters as f64)),
        ("mean_s", json::num(stats.mean.as_secs_f64())),
        ("median_s", json::num(stats.median.as_secs_f64())),
        ("min_s", json::num(stats.min.as_secs_f64())),
        ("max_s", json::num(stats.max.as_secs_f64())),
        ("stddev_s", json::num(stats.stddev.as_secs_f64())),
    ];
    pairs.extend(extra);
    json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let s = bench("noop", 2, 20, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 20);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn bench_measures_sleep_roughly() {
        let s = bench("sleep", 0, 3, || {
            std::thread::sleep(Duration::from_millis(5));
        });
        assert!(s.mean >= Duration::from_millis(4), "mean={:?}", s.mean);
        assert!(s.mean < Duration::from_millis(60), "mean={:?}", s.mean);
    }

    #[test]
    fn bench_for_respects_budget() {
        let t0 = Instant::now();
        let s = bench_for("budget", 0, Duration::from_millis(50), || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert!(s.iters >= 1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["N", "throughput", "speedup"]);
        t.row(&["1".into(), "100.0".into(), "1.00x".into()]);
        t.row(&["40".into(), "1800.0".into(), "18.00x".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("18.00x"));
        let lines: Vec<&str> = r.lines().filter(|l| l.contains('x')).collect();
        assert_eq!(lines.len(), 2);
    }
}
