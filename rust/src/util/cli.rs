//! Minimal CLI flag parser (clap stand-in).
//!
//! Grammar: `--flag value`, `--flag=value`, bare `--flag` (boolean), and
//! positional arguments. Typed getters with defaults; `usage()` renders
//! help from registered flag descriptions.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    descriptions: Vec<(String, String, String)>, // (name, default, help)
    program: String,
}

impl Args {
    pub fn parse_env() -> Self {
        let argv: Vec<String> = std::env::args().collect();
        Self::parse(&argv)
    }

    pub fn parse(argv: &[String]) -> Self {
        let mut a = Args {
            program: argv.first().cloned().unwrap_or_default(),
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    /// Register a flag for the usage string (chainable at startup).
    pub fn describe(mut self, name: &str, default: &str, help: &str) -> Self {
        self.descriptions.push((name.to_string(), default.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("usage: {} [flags]\n", self.program);
        for (n, d, h) in &self.descriptions {
            out.push_str(&format!("  --{:<24} {}  (default: {})\n", n, h, d));
        }
        out
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, name: &str, default: bool) -> bool {
        self.flags
            .get(name)
            .map(|v| matches!(v.as_str(), "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Value of `name` validated against a closed set — for mode flags
    /// like `--backend pjrt|native`, where a typo must not silently fall
    /// back to the default.
    pub fn choice(&self, name: &str, default: &str, allowed: &[&str]) -> Result<String, String> {
        let v = self.str(name, default);
        if allowed.contains(&v.as_str()) {
            Ok(v)
        } else {
            Err(format!("--{name}: expected one of {}, got '{v}'", allowed.join("|")))
        }
    }

    /// Comma-separated list of usize, e.g. `--n-values 1,2,5,10`.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.iter().map(|x| x.to_string()))
            .collect()
    }

    #[test]
    fn parses_key_value_styles() {
        // note: a bare `--flag` consumes the next token as its value unless
        // that token is another flag — positionals go before flags or after
        // `--flag=value` forms.
        let a = Args::parse(&argv(&["pos1", "--n", "5", "--mode=mux", "--verbose"]));
        assert_eq!(a.usize("n", 0), 5);
        assert_eq!(a.str("mode", ""), "mux");
        assert!(a.bool("verbose", false));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(&[]));
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.f64("rate", 1.5), 1.5);
        assert!(!a.bool("missing", false));
    }

    #[test]
    fn usize_list_parses() {
        let a = Args::parse(&argv(&["--ns", "1,2,5,10,20,40"]));
        assert_eq!(a.usize_list("ns", &[]), vec![1, 2, 5, 10, 20, 40]);
        assert_eq!(a.usize_list("other", &[3]), vec![3]);
    }

    #[test]
    fn bad_numbers_fall_back() {
        let a = Args::parse(&argv(&["--n", "abc"]));
        assert_eq!(a.usize("n", 9), 9);
    }

    #[test]
    fn choice_validates_closed_set() {
        let a = Args::parse(&argv(&["--backend", "native"]));
        assert_eq!(a.choice("backend", "pjrt", &["pjrt", "native"]).unwrap(), "native");
        assert_eq!(a.choice("missing", "pjrt", &["pjrt", "native"]).unwrap(), "pjrt");
        let bad = Args::parse(&argv(&["--backend", "tpu"]));
        let err = bad.choice("backend", "pjrt", &["pjrt", "native"]).unwrap_err();
        assert!(err.contains("pjrt|native"), "{err}");
    }
}
