//! Client-side line framing over a byte stream.
//!
//! The reactor (`coordinator/reactor.rs`) owns the *server* side of
//! newline-delimited framing; this module is the **client** mirror used
//! by the shard connection pool: bytes arrive from `read()` in arbitrary
//! fragments (split, merged, many-lines-at-once), and [`LineAssembler`]
//! turns them back into complete lines with the same oversized-line
//! policy the server applies — a line beyond `max_line` poisons the
//! stream instead of silently truncating a frame into a different,
//! syntactically valid one.

/// Incremental newline reassembler for one connection.
#[derive(Debug)]
pub struct LineAssembler {
    buf: Vec<u8>,
    max_line: usize,
    poisoned: bool,
}

/// One `feed` outcome: zero or more complete lines, or stream poison.
#[derive(Debug, PartialEq, Eq)]
pub enum FeedError {
    /// the current line exceeds `max_line` bytes with no terminator —
    /// the framing can no longer be trusted; the caller must drop the
    /// connection
    Oversized { limit: usize },
}

impl LineAssembler {
    pub fn new(max_line: usize) -> Self {
        LineAssembler { buf: Vec::new(), max_line, poisoned: false }
    }

    /// Feed a read fragment; append every newly completed line (without
    /// its `\n`, with a trailing `\r` stripped) to `out`. Returns
    /// [`FeedError::Oversized`] once the unterminated tail passes
    /// `max_line`; after that every call fails (the stream is poisoned).
    pub fn feed(&mut self, chunk: &[u8], out: &mut Vec<String>) -> Result<(), FeedError> {
        if self.poisoned {
            return Err(FeedError::Oversized { limit: self.max_line });
        }
        self.buf.extend_from_slice(chunk);
        let mut start = 0usize;
        while let Some(pos) = self.buf[start..].iter().position(|&b| b == b'\n') {
            let mut end = start + pos;
            if end > start && self.buf[end - 1] == b'\r' {
                end -= 1;
            }
            out.push(String::from_utf8_lossy(&self.buf[start..end]).into_owned());
            start += pos + 1;
        }
        self.buf.drain(..start);
        if self.buf.len() > self.max_line {
            self.poisoned = true;
            return Err(FeedError::Oversized { limit: self.max_line });
        }
        Ok(())
    }

    /// Bytes buffered waiting for a terminator.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed_ok(a: &mut LineAssembler, chunk: &[u8]) -> Vec<String> {
        let mut out = Vec::new();
        a.feed(chunk, &mut out).expect("feed within limits");
        out
    }

    #[test]
    fn split_and_merged_fragments_reassemble() {
        let mut a = LineAssembler::new(1024);
        assert!(feed_ok(&mut a, b"hel").is_empty());
        assert!(feed_ok(&mut a, b"lo").is_empty());
        assert_eq!(feed_ok(&mut a, b"\nworld\npar"), vec!["hello", "world"]);
        assert_eq!(a.pending(), 3);
        assert_eq!(feed_ok(&mut a, b"tial\n"), vec!["partial"]);
        assert_eq!(a.pending(), 0);
    }

    #[test]
    fn crlf_and_empty_lines() {
        let mut a = LineAssembler::new(64);
        assert_eq!(feed_ok(&mut a, b"a\r\n\nb\n"), vec!["a", "", "b"]);
    }

    #[test]
    fn oversized_line_poisons_the_stream() {
        let mut a = LineAssembler::new(8);
        let mut out = Vec::new();
        assert_eq!(
            a.feed(&[b'x'; 9], &mut out),
            Err(FeedError::Oversized { limit: 8 }),
            "an unterminated over-limit tail is rejected"
        );
        // poisoned: even a well-formed follow-up fails
        assert!(a.feed(b"ok\n", &mut out).is_err());
        assert!(out.is_empty());
    }

    #[test]
    fn oversized_only_counts_the_unterminated_tail() {
        let mut a = LineAssembler::new(8);
        // 30 bytes arrive, but every line inside is short: fine
        assert_eq!(feed_ok(&mut a, b"aaaa\nbbbb\ncccc\ndddd\neeee\nfff\n").len(), 6);
    }
}
