//! Minimal JSON parser / writer (serde stand-in).
//!
//! Supports the full JSON grammar, including `\uXXXX` escapes: BMP
//! code points decode directly and astral characters decode via UTF-16
//! surrogate pairs (`\uD83D\uDE00` → 😀); a lone or mismatched
//! surrogate is a clean parse error, never a silent U+FFFD. Used for
//! the artifact manifest written by `python/compile/aot.py`, the v2
//! wire protocol, and bench/experiment result files.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation (human-readable result files).
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            out.push('\n');
            for _ in 0..d {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                pad(out, depth);
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// builders (ergonomics for result files)
// ---------------------------------------------------------------------------

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    /// Read exactly four hex digits at the cursor, advancing past them.
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let hex = &self.b[self.i..self.i + 4];
        // strict: from_str_radix would also accept a leading '+'
        if hex.iter().any(|c| !c.is_ascii_hexdigit()) {
            return Err(self.err("bad \\u escape"));
        }
        let cp = u32::from_str_radix(std::str::from_utf8(hex).unwrap(), 16)
            .map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(cp)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.i += 1; // past 'u'
                            let hi = self.hex4()?;
                            let cp = match hi {
                                // high surrogate: a low surrogate escape
                                // MUST follow (UTF-16 pair -> astral char)
                                0xD800..=0xDBFF => {
                                    if self.peek() != Some(b'\\')
                                        || self.b.get(self.i + 1) != Some(&b'u')
                                    {
                                        return Err(
                                            self.err("unpaired surrogate in \\u escape")
                                        );
                                    }
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..=0xDFFF).contains(&lo) {
                                        return Err(
                                            self.err("unpaired surrogate in \\u escape")
                                        );
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                }
                                0xDC00..=0xDFFF => {
                                    return Err(self.err("unpaired surrogate in \\u escape"))
                                }
                                cp => cp,
                            };
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad \\u escape"))?,
                            );
                            continue; // cursor already past the escape
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"n": 40, "list": [1.5, "s", false], "nested": {"deep": [[]]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"caf\\u00e9 ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("café ✓"));
    }

    #[test]
    fn surrogate_pairs_decode_to_astral_chars() {
        let v = Json::parse("\"\\ud83d\\ude00\"").unwrap(); // U+1F600
        assert_eq!(v.as_str(), Some("😀"));
        let v = Json::parse("\"a\\uD834\\uDD1Eb\"").unwrap(); // U+1D11E
        assert_eq!(v.as_str(), Some("a𝄞b"));
        // the writer emits the raw char; a parse of its output round-trips
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn lone_surrogates_are_a_clean_error_not_a_replacement_char() {
        for src in [
            "\"\\ud800\"",       // high, end of string
            "\"\\ud800x\"",      // high, ordinary char follows
            "\"\\ud800\\n\"",    // high, non-\u escape follows
            "\"\\udc00\"",       // lone low
            "\"\\ud800\\ud800\"", // high followed by high
        ] {
            let e = Json::parse(src).unwrap_err();
            assert!(e.msg.contains("surrogate"), "{src}: {}", e.msg);
        }
        assert!(Json::parse("\"\\u12g4\"").is_err(), "non-hex digit");
        assert!(Json::parse("\"\\u+123\"").is_err(), "sign is not a hex digit");
        assert!(Json::parse("\"\\u12\"").is_err(), "truncated escape");
    }

    #[test]
    fn integer_formatting_stays_integral() {
        assert_eq!(Json::Num(40.0).to_string(), "40");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
