//! Latency / throughput metrics.
//!
//! `Histogram` is a log-bucketed latency histogram (HdrHistogram-lite):
//! fixed memory, ~4% relative quantile error, lock-free recording via
//! atomics so the serving hot path never takes a mutex to record.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

const BUCKETS_PER_OCTAVE: usize = 16;
const N_OCTAVES: usize = 40; // covers 1ns ..> 1000s
const N_BUCKETS: usize = BUCKETS_PER_OCTAVE * N_OCTAVES;

/// Log-bucketed histogram of nanosecond values.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        if v < 2 {
            return v as usize;
        }
        let octave = 63 - v.leading_zeros() as usize;
        let frac = ((v >> octave.saturating_sub(4)) & 0xF) as usize; // 4 mantissa bits
        (octave * BUCKETS_PER_OCTAVE + frac).min(N_BUCKETS - 1)
    }

    #[inline]
    fn bucket_mid(idx: usize) -> u64 {
        let octave = idx / BUCKETS_PER_OCTAVE;
        let frac = (idx % BUCKETS_PER_OCTAVE) as u64;
        if octave == 0 {
            return frac;
        }
        let base = 1u64 << octave;
        base + ((base / BUCKETS_PER_OCTAVE as u64).max(1)) * frac
            + (base / (2 * BUCKETS_PER_OCTAVE as u64)).max(0)
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Quantile in [0,1]; ~±4% relative error from bucketing.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_mid(i).min(self.max());
            }
        }
        self.max()
    }

    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count(),
            mean_ns: self.mean(),
            p50_ns: self.quantile(0.50),
            p95_ns: self.quantile(0.95),
            p99_ns: self.quantile(0.99),
            max_ns: self.max(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

impl LatencySummary {
    /// Approximate merge of two summaries. Count-weighted averages for
    /// mean and p50 (max would overstate the median by the full
    /// inter-lane spread when traffic is skewed to a fast lane); max for
    /// p95/p99 (a conservative bound is the right direction for tails).
    /// Exact when one side is empty.
    pub fn merge(self, o: LatencySummary) -> LatencySummary {
        if self.count == 0 {
            return o;
        }
        if o.count == 0 {
            return self;
        }
        let total = self.count + o.count;
        let weighted = |a: u64, b: u64| -> u64 {
            ((a as f64 * self.count as f64 + b as f64 * o.count as f64) / total as f64) as u64
        };
        LatencySummary {
            count: total,
            mean_ns: (self.mean_ns * self.count as f64 + o.mean_ns * o.count as f64)
                / total as f64,
            p50_ns: weighted(self.p50_ns, o.p50_ns),
            p95_ns: self.p95_ns.max(o.p95_ns),
            p99_ns: self.p99_ns.max(o.p99_ns),
            max_ns: self.max_ns.max(o.max_ns),
        }
    }

    pub fn render(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={} p50={} p95={} p99={} max={}",
            self.count,
            fmt_ns(self.mean_ns as u64),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.max_ns),
        )
    }
}

pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Monotonic counter set for serving stats.
#[derive(Default)]
pub struct Counters {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub expired: AtomicU64,
    pub groups_executed: AtomicU64,
    pub slots_padded: AtomicU64,
    /// wasted token-positions in executed content tensors: empty-slot
    /// rows plus each live row's pad tail, at the executed bucket
    /// length — `slots_padded` counts whole empty slots, this counts
    /// the finer-grained padding waste that length bucketing removes
    pub tokens_padded: AtomicU64,
    /// batcher intake drains (lock round-trips); requests/wave =
    /// submitted / intake_waves is the hot-path amortization factor
    pub intake_waves: AtomicU64,
    /// exec batches formed by the batcher — for a router lane this is
    /// the number of waves it *pulled* from the shared admission queue
    pub batches_formed: AtomicU64,
    /// times the ids scratch buffer had to grow mid-serving; 0 after
    /// warmup is the allocation-free steady-state invariant
    pub scratch_reallocs: AtomicU64,
}

impl Counters {
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            groups_executed: self.groups_executed.load(Ordering::Relaxed),
            slots_padded: self.slots_padded.load(Ordering::Relaxed),
            tokens_padded: self.tokens_padded.load(Ordering::Relaxed),
            intake_waves: self.intake_waves.load(Ordering::Relaxed),
            batches_formed: self.batches_formed.load(Ordering::Relaxed),
            scratch_reallocs: self.scratch_reallocs.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub groups_executed: u64,
    pub slots_padded: u64,
    pub tokens_padded: u64,
    pub intake_waves: u64,
    pub batches_formed: u64,
    pub scratch_reallocs: u64,
}

impl CounterSnapshot {
    /// Field-wise sum — aggregates lanes behind a router.
    pub fn merge(self, o: CounterSnapshot) -> CounterSnapshot {
        CounterSnapshot {
            submitted: self.submitted + o.submitted,
            completed: self.completed + o.completed,
            rejected: self.rejected + o.rejected,
            expired: self.expired + o.expired,
            groups_executed: self.groups_executed + o.groups_executed,
            slots_padded: self.slots_padded + o.slots_padded,
            tokens_padded: self.tokens_padded + o.tokens_padded,
            intake_waves: self.intake_waves + o.intake_waves,
            batches_formed: self.batches_formed + o.batches_formed,
            scratch_reallocs: self.scratch_reallocs + o.scratch_reallocs,
        }
    }
}

/// Wall-clock throughput meter.
pub struct Throughput {
    start: Instant,
    items: AtomicU64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: Instant::now(), items: AtomicU64::new(0) }
    }

    pub fn add(&self, n: u64) {
        self.items.fetch_add(n, Ordering::Relaxed);
    }

    pub fn per_second(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.items.load(Ordering::Relaxed) as f64 / secs
        }
    }

    pub fn total(&self) -> u64 {
        self.items.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_within_bucket_error() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 1_000); // 1us .. 10ms uniform
        }
        let p50 = h.quantile(0.5) as f64;
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.10, "p50={p50}");
        let p99 = h.quantile(0.99) as f64;
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.10, "p99={p99}");
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn histogram_empty_and_singleton() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0);
        h.record(12345);
        assert_eq!(h.count(), 1);
        let q = h.quantile(0.5) as f64;
        assert!((q - 12345.0).abs() / 12345.0 < 0.10, "q={q}");
    }

    #[test]
    fn histogram_quantile_monotone() {
        let h = Histogram::new();
        let mut r = crate::util::rng::Rng::new(9);
        for _ in 0..50_000 {
            h.record((r.f64() * 1e9) as u64 + 1);
        }
        let qs: Vec<u64> = [0.1, 0.5, 0.9, 0.99, 0.999]
            .iter()
            .map(|&q| h.quantile(q))
            .collect();
        for w in qs.windows(2) {
            assert!(w[0] <= w[1], "{qs:?}");
        }
        assert!(h.quantile(1.0) <= h.max());
    }

    #[test]
    fn histogram_concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    h.record(i + t);
                }
            }));
        }
        for x in handles {
            x.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1_500), "1.50us");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(3_200_000_000), "3.20s");
    }
}
