//! Substrate modules built from scratch for the offline environment.
//!
//! Only the `xla` crate (and `anyhow`) are depended on — vendored as
//! path crates under `rust/vendor/` — so the pieces a serving framework
//! normally pulls from the ecosystem are implemented here: JSON
//! (`json`), PRNG (`rng`), CLI parsing (`cli`),
//! a thread pool + MPMC channel (`threadpool`), latency/throughput
//! metrics (`metrics`), a criterion-style bench harness (`bench`), a
//! small property-testing helper (`proptest`), client-side line framing
//! (`framed`), seeded-jitter exponential backoff (`backoff`), and
//! instrumented lock primitives with a runtime lock-order / leak
//! detector (`sync`).

pub mod backoff;
pub mod bench;
pub mod cli;
pub mod framed;
pub mod json;
pub mod metrics;
pub mod proptest;
pub mod rng;
pub mod sync;
pub mod threadpool;
