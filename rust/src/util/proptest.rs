//! Property-testing helper (proptest stand-in).
//!
//! `check(name, cases, f)` runs `f` against `cases` independently seeded
//! RNGs; on failure it retries with the same seed to confirm determinism
//! and panics with the reproducing seed. Shrinking is approximated by
//! exposing `Gen::size_hint`, which the generator functions use to bias
//! early cases toward minimal sizes — small counterexamples are tried
//! first by construction.

use super::rng::Rng;

/// Generation context handed to property bodies.
pub struct Gen {
    pub rng: Rng,
    /// grows 0.0 -> 1.0 across the case budget; generators should scale
    /// structure sizes by it so early failures are small.
    pub size_hint: f64,
    pub case: usize,
}

impl Gen {
    /// A size in [1, max] biased by the case index (early cases small).
    pub fn sized(&mut self, max: usize) -> usize {
        let cap = ((max as f64 - 1.0) * self.size_hint).round() as usize + 1;
        self.rng.range(1, cap + 1)
    }

    pub fn vec_u32(&mut self, max_len: usize, max_val: u32) -> Vec<u32> {
        let len = self.sized(max_len);
        (0..len).map(|_| self.rng.below(max_val as usize) as u32).collect()
    }
}

/// Run a property over `cases` random cases. The body returns
/// `Err(message)` (or panics) to signal a counterexample.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = 0xDA7A_3117u64; // fixed: reproducible CI
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9);
        let mut g = Gen {
            rng: Rng::new(seed),
            size_hint: (case as f64 + 1.0) / cases as f64,
            case,
        };
        if let Err(msg) = f(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with Rng::new({seed:#x})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("reverse-reverse", 50, |g| {
            let v = g.vec_u32(32, 1000);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                Err("reverse twice != identity".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn reports_failures_with_seed() {
        check("always-fails", 10, |g| {
            let n = g.sized(100);
            if n < 10_000 {
                Err(format!("found {n}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn early_cases_are_small() {
        let mut first_sizes = Vec::new();
        check("sizes", 100, |g| {
            if g.case < 10 {
                first_sizes.push(g.sized(1000));
            }
            Ok(())
        });
        assert!(first_sizes.iter().all(|&s| s <= 120), "{first_sizes:?}");
    }
}
