//! Deterministic PRNG (rand-crate stand-in).
//!
//! SplitMix64 core — statistically solid for workload generation and
//! property tests, trivially seedable, no_std-simple. Includes the
//! samplers the workload generators need (uniform, range, normal via
//! Box-Muller, zipf via rejection-inversion, exponential for Poisson
//! arrivals, shuffle).

#[derive(Debug, Clone)]
struct ZipfCache {
    n: usize,
    a: f64,
    cdf: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    zipf_cache: Option<ZipfCache>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point and decorrelate small seeds
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), zipf_cache: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // SplitMix64 (Steele, Lea, Flood 2014)
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire's multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Zipf-distributed integer in [0, n) with exponent `a` (~token and
    /// request-popularity distributions). Inverse-CDF over a cached
    /// harmonic table — the (n, a) pair is cached so repeated sampling
    /// from the same distribution (the common case in workload
    /// generators) is a binary search.
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        debug_assert!(n > 0 && a > 0.0);
        if self
            .zipf_cache
            .as_ref()
            .map(|c| c.n != n || c.a != a)
            .unwrap_or(true)
        {
            let mut cdf = Vec::with_capacity(n);
            let mut acc = 0.0f64;
            for k in 1..=n {
                acc += (k as f64).powf(-a);
                cdf.push(acc);
            }
            let total = acc;
            for v in &mut cdf {
                *v /= total;
            }
            self.zipf_cache = Some(ZipfCache { n, a, cdf });
        }
        let u = self.f64();
        let cdf = &self.zipf_cache.as_ref().unwrap().cdf;
        cdf.partition_point(|&c| c < u).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Split off an independent stream (for per-thread rngs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_sane() {
        let mut r = Rng::new(3);
        let mean: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 16];
        for _ in 0..200_000 {
            counts[r.zipf(16, 1.3)] += 1;
        }
        assert!(counts[0] > counts[3] && counts[3] > counts[10]);
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(6);
        let mean: f64 = (0..100_000).map(|_| r.exponential(2.0)).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
