//! Instrumented lock primitives with a runtime lock-order / leak detector.
//!
//! [`TrackedMutex`] and [`TrackedCondvar`] wrap the std primitives. In
//! release builds they compile down to the plain std types (lock poisoning
//! is swallowed, no bookkeeping). In debug builds a process-wide detector
//! can be armed — `DATAMUX_LOCK_CHECK=1` in the environment, or
//! [`force_arm`] from a test — and every acquisition is checked for:
//!
//! - **lock-order inversions**: a global name-level acquired-after graph is
//!   maintained; acquiring `B` while holding `A` adds the edge `A -> B`,
//!   and any acquisition that would close a cycle (including same-name
//!   nesting of two instances) panics on the offending thread.
//! - **rank violations**: locks carry an optional rank (see [`rank`]); a
//!   ranked lock may only be acquired while every ranked lock already held
//!   has a *strictly smaller* rank. Rank `0` means unranked (exempt from
//!   rank checks, still covered by the order graph).
//! - **reentrant acquisition** of the same instance — a guaranteed
//!   deadlock with std mutexes — reported before blocking.
//! - **wait cycles**: blocked acquisitions register in a waits-for table;
//!   a cycle of threads each blocked on a lock the next one holds is
//!   reported even if the order graph never saw the pattern before.
//!
//! Violations are recorded (see [`violations`]) and raised as panics, so a
//! test can observe one with `catch_unwind`. Locked guards are counted
//! process-wide; [`assert_quiescent`] asserts none is live (i.e. leaked)
//! at a point where the process should hold nothing — call it only at true
//! quiescent points (end of `main`, single-threaded tests), never
//! mid-suite where parallel tests legitimately hold locks.

use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock ranks for the coordinator tier, lowest acquired first. A ranked
/// lock may only be acquired while all held ranked locks have strictly
/// smaller ranks; see DESIGN.md "Concurrency invariants" for the
/// hierarchy rationale.
pub mod rank {
    /// Unranked: exempt from rank checks (still in the order graph).
    pub const NONE: u32 = 0;
    /// `shards.rs` per-shard connection slot (outermost).
    pub const SHARD_CONN: u32 = 10;
    /// `shards.rs` per-shard breaker state (nested inside the conn slot
    /// on the connection-down path).
    pub const SHARD_BREAKER: u32 = 20;
    /// `pool.rs` in-flight request map.
    pub const POOL_IN_FLIGHT: u32 = 30;
    /// `pool.rs` connection writer half.
    pub const CONN_WRITER: u32 = 40;
    /// `pool.rs` / `shards.rs` thread-handle slots (reader, monitor).
    pub const THREAD_HANDLE: u32 = 50;
    /// `server.rs` staging buffers and batch accumulators.
    pub const SERVER_STAGING: u32 = 60;
    /// `dispatch.rs` adaptive gate and `mod.rs` drain meter.
    pub const DISPATCH_GATE: u32 = 70;
    /// `pool.rs` fault-injector state (innermost leaf).
    pub const FAULT_STATE: u32 = 80;
}

#[cfg(debug_assertions)]
mod detect {
    use std::cell::RefCell;
    use std::collections::{HashMap, HashSet};
    use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
    use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError, TryLockError};
    use std::thread::{self, ThreadId};

    static LIVE_GUARDS: AtomicI64 = AtomicI64::new(0);
    static FORCE: AtomicBool = AtomicBool::new(false);

    pub(super) fn armed() -> bool {
        static ENV: OnceLock<bool> = OnceLock::new();
        *ENV.get_or_init(|| std::env::var("DATAMUX_LOCK_CHECK").is_ok_and(|v| v == "1"))
            || FORCE.load(Ordering::Relaxed)
    }

    pub(super) fn force_arm() {
        FORCE.store(true, Ordering::SeqCst);
    }

    pub(super) fn next_id() -> u64 {
        static NEXT: AtomicU64 = AtomicU64::new(1);
        NEXT.fetch_add(1, Ordering::Relaxed)
    }

    pub(super) fn guard_created() {
        LIVE_GUARDS.fetch_add(1, Ordering::Relaxed);
    }

    pub(super) fn guard_dropped() {
        LIVE_GUARDS.fetch_sub(1, Ordering::Relaxed);
    }

    pub(super) fn live_guards() -> i64 {
        LIVE_GUARDS.load(Ordering::Relaxed)
    }

    #[derive(Clone, Copy)]
    struct Held {
        id: u64,
        name: &'static str,
        rank: u32,
    }

    thread_local! {
        static HELD: RefCell<Vec<Held>> = const { RefCell::new(Vec::new()) };
    }

    /// Global detector state. Guarded by a *raw* std mutex on purpose: the
    /// detector must not recurse into itself, and this lock is always a
    /// leaf held for a few map operations.
    #[derive(Default)]
    struct State {
        /// Name-level acquired-after graph: edge `A -> B` means some
        /// thread acquired `B` while holding `A`.
        edges: HashMap<&'static str, HashSet<&'static str>>,
        /// Lock instance id -> thread currently holding it.
        holders: HashMap<u64, ThreadId>,
        /// Thread -> lock instance it is blocked acquiring.
        waiting: HashMap<ThreadId, (u64, &'static str)>,
        violations: Vec<String>,
    }

    fn state() -> MutexGuard<'static, State> {
        static STATE: OnceLock<Mutex<State>> = OnceLock::new();
        STATE
            .get_or_init(|| Mutex::new(State::default()))
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    pub(super) fn violations_snapshot() -> Vec<String> {
        state().violations.clone()
    }

    fn fail(mut st: MutexGuard<'_, State>, msg: String) -> ! {
        st.violations.push(msg.clone());
        drop(st);
        panic!("{msg}");
    }

    fn is_reachable(
        edges: &HashMap<&'static str, HashSet<&'static str>>,
        from: &'static str,
        to: &'static str,
    ) -> bool {
        let mut stack = vec![from];
        let mut seen: HashSet<&'static str> = HashSet::new();
        while let Some(n) = stack.pop() {
            if n == to {
                return true;
            }
            if seen.insert(n) {
                if let Some(next) = edges.get(n) {
                    stack.extend(next.iter().copied());
                }
            }
        }
        false
    }

    /// Order / rank / reentrancy checks, run *before* blocking on the
    /// inner mutex so a guaranteed deadlock becomes a typed panic instead.
    pub(super) fn before_acquire(id: u64, name: &'static str, rank: u32) {
        let held: Vec<Held> = HELD.with(|h| h.borrow().clone());
        if held.iter().any(|e| e.id == id) {
            fail(
                state(),
                format!("reentrant acquisition of lock `{name}` (would deadlock)"),
            );
        }
        if let Some(same) = held.iter().find(|e| e.name == name) {
            fail(
                state(),
                format!(
                    "same-name nesting: acquiring a second `{}` instance while one is held",
                    same.name
                ),
            );
        }
        if rank != 0 {
            if let Some(worst) = held.iter().filter(|e| e.rank >= rank).max_by_key(|e| e.rank) {
                fail(
                    state(),
                    format!(
                        "rank inversion: acquiring `{name}` (rank {rank}) while holding `{}` \
                         (rank {})",
                        worst.name, worst.rank
                    ),
                );
            }
        }
        if held.is_empty() {
            return;
        }
        let mut st = state();
        for h in &held {
            if is_reachable(&st.edges, name, h.name) {
                let msg = format!(
                    "lock-order inversion: acquiring `{name}` while holding `{}`, but the \
                     opposite order was observed before (cycle `{name}` -> ... -> `{}`)",
                    h.name, h.name
                );
                fail(st, msg);
            }
        }
        for h in &held {
            st.edges.entry(h.name).or_default().insert(name);
        }
    }

    pub(super) fn on_acquired(id: u64, name: &'static str, rank: u32) {
        state().holders.insert(id, thread::current().id());
        HELD.with(|h| h.borrow_mut().push(Held { id, name, rank }));
    }

    pub(super) fn on_released(id: u64) {
        let me = thread::current().id();
        let mut st = state();
        if st.holders.get(&id) == Some(&me) {
            st.holders.remove(&id);
        }
        drop(st);
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(pos) = h.iter().rposition(|e| e.id == id) {
                h.remove(pos);
            }
        });
    }

    /// Follow the waits-for chain starting at `me`; panic if it loops
    /// back, which means a cycle of threads each blocked on a lock the
    /// next one holds.
    fn check_wait_cycle(mut st: MutexGuard<'_, State>, me: ThreadId) {
        let mut path: Vec<&'static str> = Vec::new();
        let mut t = me;
        for _ in 0..64 {
            let Some(&(lid, lname)) = st.waiting.get(&t) else {
                return;
            };
            path.push(lname);
            let Some(&holder) = st.holders.get(&lid) else {
                return;
            };
            if holder == me {
                let msg = format!("deadlock: wait cycle through locks [{}]", path.join(" -> "));
                st.waiting.remove(&me);
                fail(st, msg);
            }
            t = holder;
        }
    }

    /// Acquire with waits-for registration: try-lock spin with periodic
    /// wait-cycle checks instead of parking unobservably in the kernel.
    pub(super) fn blocking_lock<'a, T>(
        m: &'a Mutex<T>,
        id: u64,
        name: &'static str,
    ) -> MutexGuard<'a, T> {
        match m.try_lock() {
            Ok(g) => return g,
            Err(TryLockError::Poisoned(p)) => return p.into_inner(),
            Err(TryLockError::WouldBlock) => {}
        }
        let me = thread::current().id();
        {
            let mut st = state();
            st.waiting.insert(me, (id, name));
            check_wait_cycle(st, me);
        }
        let mut spins: u32 = 0;
        loop {
            match m.try_lock() {
                Ok(g) => {
                    state().waiting.remove(&me);
                    return g;
                }
                Err(TryLockError::Poisoned(p)) => {
                    state().waiting.remove(&me);
                    return p.into_inner();
                }
                Err(TryLockError::WouldBlock) => {}
            }
            spins = spins.wrapping_add(1);
            if spins < 64 {
                thread::yield_now();
            } else {
                thread::sleep(std::time::Duration::from_micros(500));
                if spins % 16 == 0 {
                    check_wait_cycle(state(), me);
                }
            }
        }
    }
}

/// A named, optionally ranked mutex. See the module docs for what the
/// debug-build detector checks; in release this is a plain [`Mutex`] that
/// swallows poisoning.
pub struct TrackedMutex<T> {
    name: &'static str,
    rank: u32,
    #[cfg_attr(not(debug_assertions), allow(dead_code))]
    id: u64,
    inner: Mutex<T>,
}

impl<T> TrackedMutex<T> {
    pub fn new(name: &'static str, rank: u32, value: T) -> Self {
        TrackedMutex {
            name,
            rank,
            #[cfg(debug_assertions)]
            id: detect::next_id(),
            #[cfg(not(debug_assertions))]
            id: 0,
            inner: Mutex::new(value),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }

    #[cfg(debug_assertions)]
    pub fn lock(&self) -> TrackedGuard<'_, T> {
        let tracked = detect::armed();
        let inner = if tracked {
            detect::before_acquire(self.id, self.name, self.rank);
            let g = detect::blocking_lock(&self.inner, self.id, self.name);
            detect::on_acquired(self.id, self.name, self.rank);
            g
        } else {
            self.inner.lock().unwrap_or_else(PoisonError::into_inner)
        };
        detect::guard_created();
        TrackedGuard {
            inner: Some(inner),
            lock: self,
            tracked,
        }
    }

    #[cfg(not(debug_assertions))]
    pub fn lock(&self) -> TrackedGuard<'_, T> {
        TrackedGuard {
            inner: Some(self.inner.lock().unwrap_or_else(PoisonError::into_inner)),
        }
    }

    #[cfg(debug_assertions)]
    pub fn try_lock(&self) -> Option<TrackedGuard<'_, T>> {
        let inner = match self.inner.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => return None,
        };
        let tracked = detect::armed();
        if tracked {
            // A successful try_lock still establishes ordering; check it.
            detect::before_acquire(self.id, self.name, self.rank);
            detect::on_acquired(self.id, self.name, self.rank);
        }
        detect::guard_created();
        Some(TrackedGuard {
            inner: Some(inner),
            lock: self,
            tracked,
        })
    }

    #[cfg(not(debug_assertions))]
    pub fn try_lock(&self) -> Option<TrackedGuard<'_, T>> {
        match self.inner.try_lock() {
            Ok(g) => Some(TrackedGuard { inner: Some(g) }),
            Err(std::sync::TryLockError::Poisoned(p)) => Some(TrackedGuard {
                inner: Some(p.into_inner()),
            }),
            Err(std::sync::TryLockError::WouldBlock) => None,
        }
    }
}

/// Locked guard for a [`TrackedMutex`]. `inner` is `Some` for the whole
/// guard lifetime except transiently inside a condvar wait.
pub struct TrackedGuard<'a, T> {
    inner: Option<MutexGuard<'a, T>>,
    #[cfg(debug_assertions)]
    lock: &'a TrackedMutex<T>,
    #[cfg(debug_assertions)]
    tracked: bool,
}

#[cfg(debug_assertions)]
impl<T> TrackedGuard<'_, T> {
    fn suspend_tracking(&mut self) -> bool {
        if self.tracked {
            detect::on_released(self.lock.id);
        }
        std::mem::replace(&mut self.tracked, false)
    }

    fn resume_tracking(&mut self, was_tracked: bool) {
        if was_tracked {
            detect::before_acquire(self.lock.id, self.lock.name, self.lock.rank);
            detect::on_acquired(self.lock.id, self.lock.name, self.lock.rank);
            self.tracked = true;
        }
    }
}

impl<T> Deref for TrackedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard emptied outside wait")
    }
}

impl<T> DerefMut for TrackedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard emptied outside wait")
    }
}

impl<T> Drop for TrackedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        {
            if self.tracked {
                detect::on_released(self.lock.id);
            }
            detect::guard_dropped();
        }
        // The inner MutexGuard drops here, releasing the lock.
    }
}

/// Condvar companion to [`TrackedMutex`]: waits untrack the guard while
/// the lock is released inside the wait and re-run the acquisition checks
/// on wake.
pub struct TrackedCondvar {
    inner: Condvar,
}

impl TrackedCondvar {
    pub fn new() -> Self {
        TrackedCondvar {
            inner: Condvar::new(),
        }
    }

    pub fn wait<'a, T>(&self, mut guard: TrackedGuard<'a, T>) -> TrackedGuard<'a, T> {
        #[cfg(debug_assertions)]
        let retrack = guard.suspend_tracking();
        let inner = guard.inner.take().expect("guard emptied outside wait");
        let inner = self.inner.wait(inner).unwrap_or_else(PoisonError::into_inner);
        guard.inner = Some(inner);
        #[cfg(debug_assertions)]
        guard.resume_tracking(retrack);
        guard
    }

    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: TrackedGuard<'a, T>,
        dur: Duration,
    ) -> (TrackedGuard<'a, T>, WaitTimeoutResult) {
        #[cfg(debug_assertions)]
        let retrack = guard.suspend_tracking();
        let inner = guard.inner.take().expect("guard emptied outside wait");
        let (inner, timed_out) = self
            .inner
            .wait_timeout(inner, dur)
            .unwrap_or_else(PoisonError::into_inner);
        guard.inner = Some(inner);
        #[cfg(debug_assertions)]
        guard.resume_tracking(retrack);
        (guard, timed_out)
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

impl Default for TrackedCondvar {
    fn default() -> Self {
        Self::new()
    }
}

/// True when the runtime lock checker is armed (`DATAMUX_LOCK_CHECK=1` or
/// [`force_arm`]). Always false in release builds.
#[cfg(debug_assertions)]
pub fn lock_check_armed() -> bool {
    detect::armed()
}

#[cfg(not(debug_assertions))]
pub fn lock_check_armed() -> bool {
    false
}

/// Arm the detector for the rest of the process. One-way; used by tests.
#[cfg(debug_assertions)]
pub fn force_arm() {
    detect::force_arm();
}

#[cfg(not(debug_assertions))]
pub fn force_arm() {}

/// Number of locked [`TrackedGuard`]s currently live process-wide.
/// Always 0 in release builds.
#[cfg(debug_assertions)]
pub fn live_guard_count() -> i64 {
    detect::live_guards()
}

#[cfg(not(debug_assertions))]
pub fn live_guard_count() -> i64 {
    0
}

/// Assert no locked guard is live. Call only at true quiescent points
/// (end of `main`, single-threaded tests) — mid-suite, parallel tests
/// legitimately hold locks.
#[cfg(debug_assertions)]
pub fn assert_quiescent() {
    let live = detect::live_guards();
    assert_eq!(live, 0, "leaked locked guards at shutdown: {live} still live");
}

#[cfg(not(debug_assertions))]
pub fn assert_quiescent() {}

/// Snapshot of every violation the detector has recorded this process.
#[cfg(debug_assertions)]
pub fn violations() -> Vec<String> {
    detect::violations_snapshot()
}

#[cfg(not(debug_assertions))]
pub fn violations() -> Vec<String> {
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;
    use std::thread;

    fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
        if let Some(s) = e.downcast_ref::<String>() {
            s.clone()
        } else if let Some(s) = e.downcast_ref::<&str>() {
            (*s).to_string()
        } else {
            String::new()
        }
    }

    #[test]
    fn plain_lock_and_data() {
        let m = TrackedMutex::new("t-plain", rank::NONE, 1u32);
        *m.lock() += 1;
        assert_eq!(*m.lock(), 2);
    }

    #[test]
    fn try_lock_contended_returns_none() {
        let m = TrackedMutex::new("t-try", rank::NONE, ());
        let g = m.lock();
        assert!(m.try_lock().is_none());
        drop(g);
        assert!(m.try_lock().is_some());
    }

    #[test]
    fn catches_deliberate_inversion() {
        force_arm();
        let a = TrackedMutex::new("t-inv-a", rank::NONE, ());
        let b = TrackedMutex::new("t-inv-b", rank::NONE, ());
        {
            let _ga = a.lock();
            let _gb = b.lock(); // records edge t-inv-a -> t-inv-b
        }
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _gb = b.lock();
            let _ga = a.lock(); // closes the cycle
        }))
        .expect_err("inversion must panic");
        let msg = panic_msg(err);
        assert!(msg.contains("t-inv-a"), "unexpected message: {msg}");
        assert!(
            violations().iter().any(|v| v.contains("t-inv-a")),
            "violation must be recorded"
        );
    }

    #[test]
    fn catches_rank_inversion() {
        force_arm();
        let low = TrackedMutex::new("t-rank-low", 10, ());
        let high = TrackedMutex::new("t-rank-high", 20, ());
        let _g = high.lock();
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _ = low.lock();
        }))
        .expect_err("rank inversion must panic");
        assert!(panic_msg(err).contains("rank inversion"));
    }

    #[test]
    fn catches_reentrant_acquisition() {
        force_arm();
        let m = TrackedMutex::new("t-reent", rank::NONE, ());
        let _g = m.lock();
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _ = m.lock();
        }))
        .expect_err("reentrancy must panic, not deadlock");
        assert!(panic_msg(err).contains("reentrant"));
    }

    #[test]
    fn catches_same_name_nesting() {
        force_arm();
        let a = TrackedMutex::new("t-same", rank::NONE, ());
        let b = TrackedMutex::new("t-same", rank::NONE, ());
        let _ga = a.lock();
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _ = b.lock();
        }))
        .expect_err("same-name nesting must panic");
        assert!(panic_msg(err).contains("same-name"));
    }

    #[test]
    fn consistent_order_is_clean() {
        force_arm();
        let a = TrackedMutex::new("t-ord-a", 1, ());
        let b = TrackedMutex::new("t-ord-b", 2, ());
        for _ in 0..3 {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        assert!(!violations().iter().any(|v| v.contains("t-ord-")));
    }

    #[test]
    fn contended_lock_is_correct_when_armed() {
        force_arm();
        let m = Arc::new(TrackedMutex::new("t-contend", rank::NONE, 0u64));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = Arc::clone(&m);
            handles.push(thread::spawn(move || {
                for _ in 0..200 {
                    *m.lock() += 1;
                }
            }));
        }
        for h in handles {
            h.join().expect("worker must not panic");
        }
        assert_eq!(*m.lock(), 800);
    }

    #[test]
    fn condvar_roundtrip_under_detector() {
        force_arm();
        let pair = Arc::new((
            TrackedMutex::new("t-cv", rank::NONE, false),
            TrackedCondvar::new(),
        ));
        let p2 = Arc::clone(&pair);
        let h = thread::spawn(move || {
            let (m, cv) = &*p2;
            *m.lock() = true;
            cv.notify_one();
        });
        let (m, cv) = &*pair;
        let mut g = m.lock();
        let mut rounds = 0;
        while !*g {
            let (g2, _) = cv.wait_timeout(g, Duration::from_millis(100));
            g = g2;
            rounds += 1;
            assert!(rounds < 100, "condvar wait never observed the flag");
        }
        drop(g);
        h.join().expect("notifier must not panic");
    }

    #[test]
    fn leaked_guard_detected() {
        let m = TrackedMutex::new("t-leak", rank::NONE, ());
        let g = m.lock();
        assert!(live_guard_count() >= 1);
        let err = catch_unwind(AssertUnwindSafe(assert_quiescent));
        assert!(err.is_err(), "assert_quiescent must flag a live guard");
        drop(g);
    }
}
