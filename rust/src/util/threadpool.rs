//! Thread pool + bounded MPMC channel (tokio stand-in).
//!
//! The coordinator's event loop is thread-based: worker threads pull mux
//! groups from a bounded queue (backpressure = blocking senders), and
//! request completion is signalled through a one-shot cell. Everything is
//! std-only, via the instrumented [`TrackedMutex`] / [`TrackedCondvar`]
//! wrappers so the `DATAMUX_LOCK_CHECK=1` runtime detector covers every
//! channel wait.

use crate::util::sync::{rank, TrackedCondvar, TrackedMutex};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

// ---------------------------------------------------------------------------
// bounded MPMC channel
// ---------------------------------------------------------------------------

struct ChanInner<T> {
    q: TrackedMutex<ChanState<T>>,
    not_empty: TrackedCondvar,
    not_full: TrackedCondvar,
    cap: usize,
    /// Mirror of `buf.len()`, maintained under the lock but readable
    /// without it. `len()` is called on every router pull-gate check
    /// (shared-queue depth) and by server STATS; reading an atomic
    /// keeps those observers off the hot path's mutex.
    depth: AtomicUsize,
    /// Mirror of `ChanState::closed`, readable without the lock — the
    /// router's pull batchers check for drain mode every poll tick.
    closed: AtomicBool,
}

struct ChanState<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer channel.
pub struct Channel<T> {
    inner: Arc<ChanInner<T>>,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Channel { inner: self.inner.clone() }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum SendError {
    Closed,
}

/// Non-blocking send failure: the item is handed back either way, but
/// the two causes are distinct (the admission path maps them to
/// different typed submit errors).
#[derive(Debug)]
pub enum TrySendError<T> {
    Full(T),
    Closed(T),
}

impl<T> TrySendError<T> {
    pub fn into_inner(self) -> T {
        match self {
            TrySendError::Full(t) | TrySendError::Closed(t) => t,
        }
    }
}

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        assert!(cap > 0);
        Channel {
            inner: Arc::new(ChanInner {
                q: TrackedMutex::new(
                    "util.chan",
                    rank::NONE,
                    ChanState { buf: VecDeque::new(), closed: false },
                ),
                not_empty: TrackedCondvar::new(),
                not_full: TrackedCondvar::new(),
                cap,
                depth: AtomicUsize::new(0),
                closed: AtomicBool::new(false),
            }),
        }
    }

    /// Blocking send; returns Err if the channel is closed (backpressure:
    /// blocks while full).
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut st = self.inner.q.lock();
        loop {
            if st.closed {
                return Err(SendError::Closed);
            }
            if st.buf.len() < self.inner.cap {
                st.buf.push_back(item);
                self.inner.depth.store(st.buf.len(), Ordering::Relaxed);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st);
        }
    }

    /// Non-blocking send attempt; the error distinguishes full from
    /// closed and hands the item back.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut st = self.inner.q.lock();
        if st.closed {
            return Err(TrySendError::Closed(item));
        }
        if st.buf.len() >= self.inner.cap {
            return Err(TrySendError::Full(item));
        }
        st.buf.push_back(item);
        self.inner.depth.store(st.buf.len(), Ordering::Relaxed);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; None when the channel is closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.depth.store(st.buf.len(), Ordering::Relaxed);
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st);
        }
    }

    /// Drain up to `max` items into `out` with a single lock acquisition
    /// per wakeup: blocks until at least one item is available, then
    /// appends the whole backlog (capped at `max`) in FIFO order.
    ///
    /// Returns the number of items appended — 0 only on closed+drained,
    /// or when `deadline` passes first. This is the batcher's intake
    /// primitive: under load a full wave of requests costs one mutex
    /// round-trip instead of one per request.
    pub fn recv_up_to(
        &self,
        out: &mut Vec<T>,
        max: usize,
        deadline: Option<std::time::Instant>,
    ) -> usize {
        if max == 0 {
            return 0;
        }
        let mut st = self.inner.q.lock();
        loop {
            if !st.buf.is_empty() {
                let n = max.min(st.buf.len());
                out.extend(st.buf.drain(..n));
                self.inner.depth.store(st.buf.len(), Ordering::Relaxed);
                // a multi-item drain frees several sender slots at once
                if n > 1 {
                    self.inner.not_full.notify_all();
                } else {
                    self.inner.not_full.notify_one();
                }
                return n;
            }
            if st.closed {
                return 0;
            }
            match deadline {
                None => st = self.inner.not_empty.wait(st),
                Some(dl) => {
                    let now = std::time::Instant::now();
                    if now >= dl {
                        return 0;
                    }
                    st = self.inner.not_empty.wait_timeout(st, dl - now).0;
                }
            }
        }
    }

    /// Non-blocking multi-item drain; single lock acquisition.
    pub fn try_recv_up_to(&self, out: &mut Vec<T>, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        let mut st = self.inner.q.lock();
        let n = max.min(st.buf.len());
        if n > 0 {
            out.extend(st.buf.drain(..n));
            self.inner.depth.store(st.buf.len(), Ordering::Relaxed);
            if n > 1 {
                self.inner.not_full.notify_all();
            } else {
                self.inner.not_full.notify_one();
            }
        }
        n
    }

    /// Receive with a deadline; None on timeout or closed+drained.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.inner.q.lock();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.depth.store(st.buf.len(), Ordering::Relaxed);
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, res) = self.inner.not_empty.wait_timeout(st, deadline - now);
            st = g;
            if res.timed_out() && st.buf.is_empty() {
                return None;
            }
        }
    }

    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock();
        let item = st.buf.pop_front();
        if item.is_some() {
            self.inner.depth.store(st.buf.len(), Ordering::Relaxed);
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Queue depth. Lock-free: reads the atomic mirror, so pollers
    /// (router arrivals, STATS) never contend with senders/receivers.
    pub fn len(&self) -> usize {
        self.inner.depth.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lock-free: reads the atomic mirror (pollers never contend with
    /// senders/receivers). Send/recv paths still read the authoritative
    /// flag under the lock.
    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::Acquire)
    }

    /// Close: senders fail, receivers drain then get None.
    pub fn close(&self) {
        let mut st = self.inner.q.lock();
        st.closed = true;
        self.inner.closed.store(true, Ordering::Release);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------------
// class-prioritized bounded MPMC channel
// ---------------------------------------------------------------------------

struct PrioInner<T> {
    q: TrackedMutex<PrioState<T>>,
    not_empty: TrackedCondvar,
    not_full: TrackedCondvar,
    /// capacity per class (head-of-line isolation between classes: a
    /// saturated bulk class cannot crowd high traffic out of admission)
    cap_per_class: usize,
    /// per-class mirrors of the queue depths, readable without the lock
    /// (admission overload checks and STATS poll these)
    depths: Vec<AtomicUsize>,
    /// mirror of the total depth
    depth: AtomicUsize,
    closed: AtomicBool,
}

struct PrioState<T> {
    bufs: Vec<VecDeque<T>>,
    closed: bool,
}

impl<T> PrioState<T> {
    fn total(&self) -> usize {
        self.bufs.iter().map(VecDeque::len).sum()
    }
}

/// Bounded MPMC channel with a fixed number of priority classes.
///
/// One mutex + condvar pair spans every class, so a receiver parked on
/// an empty channel wakes on an arrival in *any* class — the property a
/// vector of independent [`Channel`]s cannot give a single parked
/// batcher. Receivers drain class 0 (highest) fully before touching
/// class 1, and so on: strict priority, by design. Each class has its
/// own capacity, so shedding pressure in a low class never consumes a
/// higher class's admission slots.
pub struct PrioChannel<T> {
    inner: Arc<PrioInner<T>>,
}

impl<T> Clone for PrioChannel<T> {
    fn clone(&self) -> Self {
        PrioChannel { inner: self.inner.clone() }
    }
}

impl<T> PrioChannel<T> {
    pub fn bounded(classes: usize, cap_per_class: usize) -> Self {
        assert!(classes > 0 && cap_per_class > 0);
        PrioChannel {
            inner: Arc::new(PrioInner {
                q: TrackedMutex::new(
                    "util.prio",
                    rank::NONE,
                    PrioState {
                        bufs: (0..classes).map(|_| VecDeque::new()).collect(),
                        closed: false,
                    },
                ),
                not_empty: TrackedCondvar::new(),
                not_full: TrackedCondvar::new(),
                cap_per_class,
                depths: (0..classes).map(|_| AtomicUsize::new(0)).collect(),
                depth: AtomicUsize::new(0),
                closed: AtomicBool::new(false),
            }),
        }
    }

    pub fn classes(&self) -> usize {
        self.inner.depths.len()
    }

    fn mirror(&self, st: &PrioState<T>, class: usize) {
        self.inner.depths[class].store(st.bufs[class].len(), Ordering::Relaxed);
        self.inner.depth.store(st.total(), Ordering::Relaxed);
    }

    /// Blocking send into `class` (0 = highest); blocks while that
    /// class is at capacity, errs when closed.
    pub fn send(&self, item: T, class: usize) -> Result<(), SendError> {
        let mut st = self.inner.q.lock();
        loop {
            if st.closed {
                return Err(SendError::Closed);
            }
            if st.bufs[class].len() < self.inner.cap_per_class {
                st.bufs[class].push_back(item);
                self.mirror(&st, class);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st);
        }
    }

    /// Non-blocking send into `class`; distinguishes the class being
    /// full from the channel being closed and hands the item back.
    pub fn try_send(&self, item: T, class: usize) -> Result<(), TrySendError<T>> {
        let mut st = self.inner.q.lock();
        if st.closed {
            return Err(TrySendError::Closed(item));
        }
        if st.bufs[class].len() >= self.inner.cap_per_class {
            return Err(TrySendError::Full(item));
        }
        st.bufs[class].push_back(item);
        self.mirror(&st, class);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Drain up to `max` items into `out`, highest class first, with a
    /// single lock acquisition per wakeup (see [`Channel::recv_up_to`]).
    /// Returns 0 only on closed+drained or an elapsed `deadline`.
    pub fn recv_up_to(
        &self,
        out: &mut Vec<T>,
        max: usize,
        deadline: Option<std::time::Instant>,
    ) -> usize {
        if max == 0 {
            return 0;
        }
        let mut st = self.inner.q.lock();
        loop {
            let n = self.drain_locked(&mut st, out, max);
            if n > 0 {
                if n > 1 {
                    self.inner.not_full.notify_all();
                } else {
                    self.inner.not_full.notify_one();
                }
                return n;
            }
            if st.closed {
                return 0;
            }
            match deadline {
                None => st = self.inner.not_empty.wait(st),
                Some(dl) => {
                    let now = std::time::Instant::now();
                    if now >= dl {
                        return 0;
                    }
                    st = self.inner.not_empty.wait_timeout(st, dl - now).0;
                }
            }
        }
    }

    /// Non-blocking multi-item drain, highest class first.
    pub fn try_recv_up_to(&self, out: &mut Vec<T>, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        let mut st = self.inner.q.lock();
        let n = self.drain_locked(&mut st, out, max);
        if n > 1 {
            self.inner.not_full.notify_all();
        } else if n == 1 {
            self.inner.not_full.notify_one();
        }
        n
    }

    fn drain_locked(&self, st: &mut PrioState<T>, out: &mut Vec<T>, max: usize) -> usize {
        let mut taken = 0;
        for class in 0..st.bufs.len() {
            if taken >= max {
                break;
            }
            let n = (max - taken).min(st.bufs[class].len());
            if n > 0 {
                out.extend(st.bufs[class].drain(..n));
                self.mirror(st, class);
                taken += n;
            }
        }
        taken
    }

    /// Total queued depth across classes (lock-free mirror).
    pub fn len(&self) -> usize {
        self.inner.depth.load(Ordering::Relaxed)
    }

    /// Queued depth of exactly `class` (lock-free mirror).
    pub fn depth_class(&self, class: usize) -> usize {
        self.inner.depths[class].load(Ordering::Relaxed)
    }

    /// Queued depth of `class` and every higher class — the work that
    /// drains before a new arrival of `class` (lock-free mirrors).
    pub fn depth_at_or_above(&self, class: usize) -> usize {
        self.inner.depths[..=class]
            .iter()
            .map(|d| d.load(Ordering::Relaxed))
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::Acquire)
    }

    /// Close: senders fail, receivers drain then get 0.
    pub fn close(&self) {
        let mut st = self.inner.q.lock();
        st.closed = true;
        self.inner.closed.store(true, Ordering::Release);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------------
// one-shot completion cell (request -> response handoff)
// ---------------------------------------------------------------------------

struct OnceInner<T> {
    slot: TrackedMutex<Option<T>>,
    cv: TrackedCondvar,
}

/// One-shot value cell: the scheduler fulfills it, the caller waits on it.
pub struct OnceCellSync<T> {
    inner: Arc<OnceInner<T>>,
}

impl<T> Clone for OnceCellSync<T> {
    fn clone(&self) -> Self {
        OnceCellSync { inner: self.inner.clone() }
    }
}

impl<T> Default for OnceCellSync<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> OnceCellSync<T> {
    pub fn new() -> Self {
        OnceCellSync {
            inner: Arc::new(OnceInner {
                slot: TrackedMutex::new("util.once", rank::NONE, None),
                cv: TrackedCondvar::new(),
            }),
        }
    }

    pub fn set(&self, v: T) {
        let mut s = self.inner.slot.lock();
        debug_assert!(s.is_none(), "OnceCellSync set twice");
        *s = Some(v);
        self.inner.cv.notify_all();
    }

    pub fn wait(&self) -> T {
        let mut s = self.inner.slot.lock();
        loop {
            if let Some(v) = s.take() {
                return v;
            }
            s = self.inner.cv.wait(s);
        }
    }

    pub fn wait_timeout(&self, dur: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + dur;
        let mut s = self.inner.slot.lock();
        loop {
            if let Some(v) = s.take() {
                return Some(v);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            s = self.inner.cv.wait_timeout(s, deadline - now).0;
        }
    }
}

// ---------------------------------------------------------------------------
// thread pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are closures; `join` waits for queue drain
/// and worker exit.
pub struct ThreadPool {
    chan: Channel<Job>,
    workers: Vec<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(n_workers: usize, queue_cap: usize) -> Self {
        let chan: Channel<Job> = Channel::bounded(queue_cap.max(1));
        let shutdown = Arc::new(AtomicBool::new(false));
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let c = chan.clone();
                std::thread::Builder::new()
                    .name(format!("datamux-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = c.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { chan, workers, shutdown }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.chan.send(Box::new(f)).expect("pool closed");
    }

    pub fn queue_len(&self) -> usize {
        self.chan.len()
    }

    /// Worker threads in the pool (fan-out width for fork-join callers).
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Close the queue and wait for all workers to finish outstanding jobs.
    pub fn join(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.chan.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.chan.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;
    use std::time::Duration;

    #[test]
    fn channel_fifo_order_single_consumer() {
        let c = Channel::bounded(16);
        for i in 0..10 {
            c.send(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(c.recv(), Some(i));
        }
    }

    #[test]
    fn channel_backpressure_blocks_then_releases() {
        let c = Channel::bounded(1);
        c.send(1u32).unwrap();
        assert!(matches!(c.try_send(2), Err(TrySendError::Full(2))));
        let c2 = c.clone();
        let h = std::thread::spawn(move || c2.send(2).unwrap());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(c.recv(), Some(1));
        h.join().unwrap();
        assert_eq!(c.recv(), Some(2));
    }

    #[test]
    fn channel_close_drains_then_none() {
        let c = Channel::bounded(8);
        c.send(1).unwrap();
        c.close();
        assert_eq!(c.recv(), Some(1));
        assert_eq!(c.recv(), None);
        assert_eq!(c.send(2), Err(SendError::Closed));
        assert!(matches!(c.try_send(3), Err(TrySendError::Closed(3))));
    }

    #[test]
    fn channel_recv_timeout_expires() {
        let c: Channel<u32> = Channel::bounded(1);
        let t0 = std::time::Instant::now();
        assert_eq!(c.recv_timeout(Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn mpmc_no_loss_no_duplication() {
        let c = Channel::bounded(4);
        let n_items = 1000usize;
        let seen = Arc::new(Mutex::new(vec![0u8; n_items]));
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let c = c.clone();
            let seen = seen.clone();
            consumers.push(std::thread::spawn(move || {
                while let Some(i) = c.recv() {
                    let mut s = seen.lock().unwrap();
                    s[i as usize] += 1;
                }
            }));
        }
        let mut producers = Vec::new();
        for p in 0..2 {
            let c = c.clone();
            producers.push(std::thread::spawn(move || {
                for i in (p..n_items).step_by(2) {
                    c.send(i).unwrap();
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        c.close();
        for h in consumers {
            h.join().unwrap();
        }
        let s = seen.lock().unwrap();
        assert!(s.iter().all(|&x| x == 1), "every item exactly once");
    }

    #[test]
    fn recv_up_to_drains_waves_in_fifo_order() {
        let c = Channel::bounded(64);
        for i in 0..20 {
            c.send(i).unwrap();
        }
        let mut out = Vec::new();
        // one lock acquisition grabs a whole wave, capped at max
        assert_eq!(c.recv_up_to(&mut out, 8, None), 8);
        assert_eq!(c.try_recv_up_to(&mut out, 100), 12);
        assert_eq!(out, (0..20).collect::<Vec<_>>());
        assert_eq!(c.len(), 0);
        assert_eq!(c.try_recv_up_to(&mut out, 4), 0);
    }

    #[test]
    fn recv_up_to_deadline_expires_and_close_drains() {
        let c: Channel<u32> = Channel::bounded(4);
        let mut out = Vec::new();
        let t0 = std::time::Instant::now();
        let dl = t0 + Duration::from_millis(30);
        assert_eq!(c.recv_up_to(&mut out, 4, Some(dl)), 0);
        assert!(t0.elapsed() >= Duration::from_millis(25));
        c.send(7).unwrap();
        c.close();
        // closed channels still drain their backlog, then report 0
        assert_eq!(c.recv_up_to(&mut out, 4, None), 1);
        assert_eq!(out, vec![7]);
        assert_eq!(c.recv_up_to(&mut out, 4, None), 0);
    }

    /// Property: mixed single/wave receivers over concurrent producers
    /// lose nothing, duplicate nothing, and a single consumer always
    /// observes FIFO order regardless of wave sizes.
    #[test]
    fn prop_recv_up_to_no_loss_no_duplication_fifo() {
        crate::util::proptest::check("recv_up_to exactly-once fifo", 40, |g| {
            let n_items = g.sized(400);
            let cap = g.sized(32);
            let c = Channel::bounded(cap);
            let producer = {
                let c = c.clone();
                std::thread::spawn(move || {
                    for i in 0..n_items {
                        c.send(i).unwrap();
                    }
                    c.close();
                })
            };
            let mut got: Vec<usize> = Vec::with_capacity(n_items);
            loop {
                // alternate wave drains with single recvs, random widths
                let wave = g.rng.range(1, 17);
                if g.rng.below(4) == 0 {
                    match c.recv() {
                        Some(i) => got.push(i),
                        None => break,
                    }
                } else if c.recv_up_to(&mut got, wave, None) == 0 {
                    break;
                }
            }
            producer.join().unwrap();
            if got.len() != n_items {
                return Err(format!("lost/duplicated: got {} of {n_items}", got.len()));
            }
            for (want, &have) in got.iter().enumerate() {
                if want != have {
                    return Err(format!("order violated at {want}: {have}"));
                }
            }
            Ok(())
        });
    }

    /// Property: multi-consumer wave drains still deliver exactly once.
    #[test]
    fn prop_recv_up_to_mpmc_exactly_once() {
        crate::util::proptest::check("recv_up_to mpmc exactly-once", 15, |g| {
            let n_items = g.sized(300);
            let c = Channel::bounded(8);
            let seen = Arc::new(Mutex::new(vec![0u8; n_items]));
            let mut consumers = Vec::new();
            for _ in 0..3 {
                let c = c.clone();
                let seen = seen.clone();
                consumers.push(std::thread::spawn(move || {
                    let mut buf = Vec::new();
                    while c.recv_up_to(&mut buf, 5, None) > 0 {
                        let mut s = seen.lock().unwrap();
                        for &i in &buf {
                            s[i] += 1;
                        }
                        buf.clear();
                    }
                }));
            }
            for i in 0..n_items {
                c.send(i).unwrap();
            }
            c.close();
            for h in consumers {
                h.join().unwrap();
            }
            let s = seen.lock().unwrap();
            match s.iter().position(|&x| x != 1) {
                None => Ok(()),
                Some(i) => Err(format!("item {i} delivered {} times", s[i])),
            }
        });
    }

    #[test]
    fn len_is_lock_free_mirror() {
        let c = Channel::bounded(8);
        assert_eq!(c.len(), 0);
        c.send(1).unwrap();
        c.send(2).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.recv(), Some(1));
        assert_eq!(c.len(), 1);
        let mut out = Vec::new();
        c.try_recv_up_to(&mut out, 8);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn prio_channel_drains_highest_class_first_fifo_within_class() {
        let c: PrioChannel<u32> = PrioChannel::bounded(3, 8);
        c.send(20, 2).unwrap();
        c.send(10, 1).unwrap();
        c.send(0, 0).unwrap();
        c.send(21, 2).unwrap();
        c.send(1, 0).unwrap();
        let mut out = Vec::new();
        assert_eq!(c.recv_up_to(&mut out, 16, None), 5);
        assert_eq!(out, vec![0, 1, 10, 20, 21]);
    }

    #[test]
    fn prio_channel_caps_are_per_class() {
        let c: PrioChannel<u32> = PrioChannel::bounded(2, 1);
        c.send(1, 1).unwrap();
        assert!(matches!(c.try_send(2, 1), Err(TrySendError::Full(2))));
        // a full low class never consumes the high class's slots
        c.try_send(3, 0).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.depth_at_or_above(0), 1);
        assert_eq!(c.depth_at_or_above(1), 2);
        let mut out = Vec::new();
        assert_eq!(c.try_recv_up_to(&mut out, 1), 1);
        assert_eq!(out, vec![3], "high drains before the earlier-queued low item");
    }

    #[test]
    fn prio_channel_parked_receiver_wakes_on_any_class() {
        let c: PrioChannel<u32> = PrioChannel::bounded(3, 4);
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            let mut out = Vec::new();
            c2.recv_up_to(&mut out, 4, None);
            out
        });
        std::thread::sleep(Duration::from_millis(20));
        c.send(7, 2).unwrap(); // lowest class still wakes the receiver
        assert_eq!(h.join().unwrap(), vec![7]);
    }

    #[test]
    fn prio_channel_close_drains_then_zero() {
        let c: PrioChannel<u32> = PrioChannel::bounded(2, 4);
        c.send(1, 1).unwrap();
        c.close();
        assert_eq!(c.send(2, 0), Err(SendError::Closed));
        let mut out = Vec::new();
        assert_eq!(c.recv_up_to(&mut out, 4, None), 1);
        assert_eq!(c.recv_up_to(&mut out, 4, None), 0);
        assert!(c.is_closed());
    }

    #[test]
    fn prio_channel_deadline_expires() {
        let c: PrioChannel<u32> = PrioChannel::bounded(2, 4);
        let mut out = Vec::new();
        let t0 = std::time::Instant::now();
        let dl = t0 + Duration::from_millis(30);
        assert_eq!(c.recv_up_to(&mut out, 4, Some(dl)), 0);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    /// Property: a concurrent producer spraying items across classes +
    /// wave receivers lose nothing and duplicate nothing.
    #[test]
    fn prop_prio_channel_exactly_once() {
        crate::util::proptest::check("prio exactly-once", 25, |g| {
            let n_items = g.sized(300);
            let classes = 3;
            let c: PrioChannel<usize> = PrioChannel::bounded(classes, 16);
            let producer = {
                let c = c.clone();
                let seed = g.rng.below(1 << 30) as u64;
                std::thread::spawn(move || {
                    let mut r = crate::util::rng::Rng::new(seed);
                    for i in 0..n_items {
                        c.send(i, r.below(classes)).unwrap();
                    }
                    c.close();
                })
            };
            let mut got: Vec<usize> = Vec::with_capacity(n_items);
            loop {
                let wave = g.rng.range(1, 9);
                if c.recv_up_to(&mut got, wave, None) == 0 {
                    break;
                }
            }
            producer.join().unwrap();
            if got.len() != n_items {
                return Err(format!("lost/duplicated: got {} of {n_items}", got.len()));
            }
            let mut sorted = got.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != n_items {
                return Err("duplicate delivery".into());
            }
            Ok(())
        });
    }

    #[test]
    fn oncecell_handoff() {
        let cell = OnceCellSync::new();
        let c2 = cell.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            c2.set(41u32);
        });
        assert_eq!(cell.wait(), 41);
        h.join().unwrap();
    }

    #[test]
    fn oncecell_timeout() {
        let cell: OnceCellSync<u32> = OnceCellSync::new();
        assert_eq!(cell.wait_timeout(Duration::from_millis(20)), None);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, 8);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }
}
