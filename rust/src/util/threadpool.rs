//! Thread pool + bounded MPMC channel (tokio stand-in).
//!
//! The coordinator's event loop is thread-based: worker threads pull mux
//! groups from a bounded queue (backpressure = blocking senders), and
//! request completion is signalled through a one-shot cell. Everything is
//! std-only: `Mutex` + `Condvar`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

// ---------------------------------------------------------------------------
// bounded MPMC channel
// ---------------------------------------------------------------------------

struct ChanInner<T> {
    q: Mutex<ChanState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct ChanState<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer channel.
pub struct Channel<T> {
    inner: Arc<ChanInner<T>>,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Channel { inner: self.inner.clone() }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum SendError {
    Closed,
}

/// Non-blocking send failure: the item is handed back either way, but
/// the two causes are distinct (the admission path maps them to
/// different typed submit errors).
#[derive(Debug)]
pub enum TrySendError<T> {
    Full(T),
    Closed(T),
}

impl<T> TrySendError<T> {
    pub fn into_inner(self) -> T {
        match self {
            TrySendError::Full(t) | TrySendError::Closed(t) => t,
        }
    }
}

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        assert!(cap > 0);
        Channel {
            inner: Arc::new(ChanInner {
                q: Mutex::new(ChanState { buf: VecDeque::new(), closed: false }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                cap,
            }),
        }
    }

    /// Blocking send; returns Err if the channel is closed (backpressure:
    /// blocks while full).
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError::Closed);
            }
            if st.buf.len() < self.inner.cap {
                st.buf.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send attempt; the error distinguishes full from
    /// closed and hands the item back.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed {
            return Err(TrySendError::Closed(item));
        }
        if st.buf.len() >= self.inner.cap {
            return Err(TrySendError::Full(item));
        }
        st.buf.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; None when the channel is closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with a deadline; None on timeout or closed+drained.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, res) = self.inner.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = g;
            if res.timed_out() && st.buf.is_empty() {
                return None;
            }
        }
    }

    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        let item = st.buf.pop_front();
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.q.lock().unwrap().closed
    }

    /// Close: senders fail, receivers drain then get None.
    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------------
// one-shot completion cell (request -> response handoff)
// ---------------------------------------------------------------------------

struct OnceInner<T> {
    slot: Mutex<Option<T>>,
    cv: Condvar,
}

/// One-shot value cell: the scheduler fulfills it, the caller waits on it.
pub struct OnceCellSync<T> {
    inner: Arc<OnceInner<T>>,
}

impl<T> Clone for OnceCellSync<T> {
    fn clone(&self) -> Self {
        OnceCellSync { inner: self.inner.clone() }
    }
}

impl<T> Default for OnceCellSync<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> OnceCellSync<T> {
    pub fn new() -> Self {
        OnceCellSync {
            inner: Arc::new(OnceInner { slot: Mutex::new(None), cv: Condvar::new() }),
        }
    }

    pub fn set(&self, v: T) {
        let mut s = self.inner.slot.lock().unwrap();
        debug_assert!(s.is_none(), "OnceCellSync set twice");
        *s = Some(v);
        self.inner.cv.notify_all();
    }

    pub fn wait(&self) -> T {
        let mut s = self.inner.slot.lock().unwrap();
        loop {
            if let Some(v) = s.take() {
                return v;
            }
            s = self.inner.cv.wait(s).unwrap();
        }
    }

    pub fn wait_timeout(&self, dur: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + dur;
        let mut s = self.inner.slot.lock().unwrap();
        loop {
            if let Some(v) = s.take() {
                return Some(v);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            s = self.inner.cv.wait_timeout(s, deadline - now).unwrap().0;
        }
    }
}

// ---------------------------------------------------------------------------
// thread pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are closures; `join` waits for queue drain
/// and worker exit.
pub struct ThreadPool {
    chan: Channel<Job>,
    workers: Vec<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(n_workers: usize, queue_cap: usize) -> Self {
        let chan: Channel<Job> = Channel::bounded(queue_cap.max(1));
        let shutdown = Arc::new(AtomicBool::new(false));
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let c = chan.clone();
                std::thread::Builder::new()
                    .name(format!("datamux-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = c.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { chan, workers, shutdown }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.chan.send(Box::new(f)).expect("pool closed");
    }

    pub fn queue_len(&self) -> usize {
        self.chan.len()
    }

    /// Close the queue and wait for all workers to finish outstanding jobs.
    pub fn join(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.chan.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.chan.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn channel_fifo_order_single_consumer() {
        let c = Channel::bounded(16);
        for i in 0..10 {
            c.send(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(c.recv(), Some(i));
        }
    }

    #[test]
    fn channel_backpressure_blocks_then_releases() {
        let c = Channel::bounded(1);
        c.send(1u32).unwrap();
        assert!(matches!(c.try_send(2), Err(TrySendError::Full(2))));
        let c2 = c.clone();
        let h = std::thread::spawn(move || c2.send(2).unwrap());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(c.recv(), Some(1));
        h.join().unwrap();
        assert_eq!(c.recv(), Some(2));
    }

    #[test]
    fn channel_close_drains_then_none() {
        let c = Channel::bounded(8);
        c.send(1).unwrap();
        c.close();
        assert_eq!(c.recv(), Some(1));
        assert_eq!(c.recv(), None);
        assert_eq!(c.send(2), Err(SendError::Closed));
        assert!(matches!(c.try_send(3), Err(TrySendError::Closed(3))));
    }

    #[test]
    fn channel_recv_timeout_expires() {
        let c: Channel<u32> = Channel::bounded(1);
        let t0 = std::time::Instant::now();
        assert_eq!(c.recv_timeout(Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn mpmc_no_loss_no_duplication() {
        let c = Channel::bounded(4);
        let n_items = 1000usize;
        let seen = Arc::new(Mutex::new(vec![0u8; n_items]));
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let c = c.clone();
            let seen = seen.clone();
            consumers.push(std::thread::spawn(move || {
                while let Some(i) = c.recv() {
                    let mut s = seen.lock().unwrap();
                    s[i as usize] += 1;
                }
            }));
        }
        let mut producers = Vec::new();
        for p in 0..2 {
            let c = c.clone();
            producers.push(std::thread::spawn(move || {
                for i in (p..n_items).step_by(2) {
                    c.send(i).unwrap();
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        c.close();
        for h in consumers {
            h.join().unwrap();
        }
        let s = seen.lock().unwrap();
        assert!(s.iter().all(|&x| x == 1), "every item exactly once");
    }

    #[test]
    fn oncecell_handoff() {
        let cell = OnceCellSync::new();
        let c2 = cell.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            c2.set(41u32);
        });
        assert_eq!(cell.wait(), 41);
        h.join().unwrap();
    }

    #[test]
    fn oncecell_timeout() {
        let cell: OnceCellSync<u32> = OnceCellSync::new();
        assert_eq!(cell.wait_timeout(Duration::from_millis(20)), None);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, 8);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }
}
