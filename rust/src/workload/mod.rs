//! Workload generation and load drivers.
//!
//! Two sources:
//!   * `EvalSet` — labelled samples exported by `python/compile/aot.py`
//!     (same generator that produced the training data), used by the
//!     accuracy-through-rust examples.
//!   * `RandomWorkload` — zipfian token text, used by the throughput
//!     benches where labels don't matter.
//!
//! Drivers:
//!   * `closed_loop` — k concurrent clients, each submit-wait-repeat
//!     (the paper's Fig 4c throughput measurement shape).
//!   * `open_loop`  — Poisson arrivals at a target rate (latency-under-
//!     load bench); unsubmittable requests count as rejected.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{Submit, SubmitError};
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// labelled eval sets (exported by aot.py)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct EvalSample {
    /// framed token text (with [CLS]/[SEP], no padding)
    pub text: String,
    /// sentence label, or first token tag for token tasks
    pub label: i64,
    /// per-position tags for token-level tasks (empty otherwise)
    pub tags: Vec<i64>,
}

#[derive(Debug)]
pub struct EvalSet {
    pub task: String,
    pub seq_len: usize,
    pub n_classes: usize,
    pub token_level: bool,
    pub samples: Vec<EvalSample>,
}

impl EvalSet {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("eval set: {e}"))?;
        let samples = root
            .get("samples")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("eval set missing samples"))?
            .iter()
            .map(|s| -> Result<EvalSample> {
                Ok(EvalSample {
                    text: s
                        .get("text")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("sample missing text"))?
                        .to_string(),
                    label: s.get("label").and_then(Json::as_i64).unwrap_or(-1),
                    tags: s
                        .get("tags")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_i64).collect())
                        .unwrap_or_default(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(EvalSet {
            task: root
                .get("task")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            seq_len: root.get("seq_len").and_then(Json::as_usize).unwrap_or(16),
            n_classes: root.get("n_classes").and_then(Json::as_usize).unwrap_or(2),
            token_level: root.get("token_level").and_then(Json::as_bool).unwrap_or(false),
            samples,
        })
    }

    /// Pre-tokenize all samples into framed rows for a given seq_len.
    pub fn framed_rows(&self, tok: &Tokenizer, seq_len: usize) -> Result<Vec<Vec<i32>>> {
        self.samples
            .iter()
            .map(|s| {
                let mut row = tok.encode(&s.text).map_err(|e| anyhow!("tokenize: {e}"))?;
                row.truncate(seq_len);
                row.resize(seq_len, tok.vocab.pad);
                Ok(row)
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// random workload (throughput benches)
// ---------------------------------------------------------------------------

pub struct RandomWorkload {
    rng: Rng,
    pub n_content: usize,
    pub body_len: usize,
}

impl RandomWorkload {
    pub fn new(seed: u64, n_content: usize, body_len: usize) -> Self {
        RandomWorkload { rng: Rng::new(seed), n_content, body_len }
    }

    /// One framed content row (ids), zipfian tokens (wikitext-ish).
    pub fn framed_row(&mut self, tok: &Tokenizer, seq_len: usize) -> Vec<i32> {
        let mut row = Vec::with_capacity(seq_len);
        row.push(tok.vocab.cls);
        for _ in 0..self.body_len.min(seq_len - 2) {
            let k = self.rng.zipf(self.n_content, 1.3);
            row.push(tok.vocab.content_base + k as i32);
        }
        row.push(tok.vocab.sep);
        row.truncate(seq_len);
        row.resize(seq_len, tok.vocab.pad);
        row
    }

    /// Token-text form of a row (exercises the tokenize path).
    pub fn text(&mut self) -> String {
        let mut words = Vec::with_capacity(self.body_len);
        for _ in 0..self.body_len {
            words.push(format!("t{}", self.rng.zipf(self.n_content, 1.3)));
        }
        words.join(" ")
    }
}

// ---------------------------------------------------------------------------
// load drivers
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct LoadReport {
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub wall: Duration,
    pub throughput_rps: f64,
}

/// Closed-loop driver: `clients` threads, each submitting `per_client`
/// requests back-to-back (submit -> wait -> next). Rows are cycled from
/// `rows`. This is the Fig 4c measurement shape: offered load always
/// saturates the engine. Generic over [`Submit`], so it drives a
/// coordinator and an adaptive-N router identically.
pub fn closed_loop<S: Submit + ?Sized + 'static>(
    engine: &Arc<S>,
    rows: &Arc<Vec<Vec<i32>>>,
    clients: usize,
    per_client: usize,
) -> LoadReport {
    let completed = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let engine = engine.clone();
        let rows = rows.clone();
        let completed = completed.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_client {
                let row = rows[(c * per_client + i) % rows.len()].clone();
                match engine.submit_framed(row) {
                    Ok(h) => {
                        if h.wait().is_err() {
                            return;
                        }
                        completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => return,
                }
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed();
    let done = completed.load(Ordering::Relaxed);
    LoadReport {
        submitted: clients * per_client,
        completed: done,
        rejected: clients * per_client - done,
        wall,
        throughput_rps: done as f64 / wall.as_secs_f64(),
    }
}

/// Offline batch pass (the paper's Fig 4c measurement shape: a full
/// dataset pass, throughput = items / wall). All requests are enqueued up
/// front so the batcher always forms *full* mux groups; the engine's
/// queue must be sized >= total.
pub fn batch_pass<S: Submit + ?Sized>(
    engine: &Arc<S>,
    rows: &[Vec<i32>],
    total: usize,
) -> LoadReport {
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(total);
    for i in 0..total {
        match engine.submit_framed(rows[i % rows.len()].clone()) {
            Ok(h) => handles.push(h),
            Err(_) => break,
        }
    }
    let mut completed = 0usize;
    for h in &handles {
        if h.wait().is_ok() {
            completed += 1;
        }
    }
    let wall = t0.elapsed();
    LoadReport {
        submitted: total,
        completed,
        rejected: total - completed,
        wall,
        throughput_rps: completed as f64 / wall.as_secs_f64(),
    }
}

/// Open-loop driver: Poisson arrivals at `rate_rps` for `duration`.
/// Returns when all accepted requests have completed. Queue-full
/// rejections count as rejected; a shut-down engine stops the run.
pub fn open_loop<S: Submit + ?Sized>(
    engine: &Arc<S>,
    rows: &Arc<Vec<Vec<i32>>>,
    rate_rps: f64,
    duration: Duration,
    seed: u64,
) -> LoadReport {
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut rejected = 0usize;
    let mut handles = Vec::new();
    let mut next_at = Duration::ZERO;
    while next_at < duration {
        let now = t0.elapsed();
        if now < next_at {
            std::thread::sleep(next_at - now);
        }
        let row = rows[submitted % rows.len()].clone();
        match engine.try_submit_framed(row) {
            Ok(h) => handles.push(h),
            Err(SubmitError::QueueFull) => rejected += 1,
            Err(_) => {
                // shutdown or misconfiguration: count it and stop
                rejected += 1;
                submitted += 1;
                break;
            }
        }
        submitted += 1;
        next_at += Duration::from_secs_f64(rng.exponential(rate_rps));
    }
    let mut completed = 0usize;
    for h in &handles {
        if h.wait().is_ok() {
            completed += 1;
        }
    }
    let wall = t0.elapsed();
    LoadReport {
        submitted,
        completed,
        rejected,
        wall,
        throughput_rps: completed as f64 / wall.as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::{default_vocab, Tokenizer};

    #[test]
    fn random_rows_are_framed() {
        let tok = Tokenizer::new(default_vocab(), 300);
        let mut w = RandomWorkload::new(7, 256, 10);
        for _ in 0..50 {
            let row = w.framed_row(&tok, 16);
            assert_eq!(row.len(), 16);
            assert_eq!(row[0], tok.vocab.cls);
            assert!(row.iter().all(|&t| t < 300));
        }
    }

    #[test]
    fn random_text_tokenizes() {
        let tok = Tokenizer::new(default_vocab(), 300);
        let mut w = RandomWorkload::new(8, 256, 12);
        let text = w.text();
        assert!(tok.encode(&text).is_ok());
    }

    #[test]
    fn eval_set_parses() {
        let json = r#"{
            "task": "mnli", "seq_len": 16, "n_classes": 3, "token_level": false,
            "samples": [
                {"text": "[CLS] t1 [SEP] t2 [SEP]", "label": 2},
                {"text": "[CLS] t3 [SEP]", "label": 0, "tags": [0, 1]}
            ]
        }"#;
        let dir = std::env::temp_dir().join("datamux_test_eval.json");
        std::fs::write(&dir, json).unwrap();
        let es = EvalSet::load(&dir).unwrap();
        assert_eq!(es.task, "mnli");
        assert_eq!(es.samples.len(), 2);
        assert_eq!(es.samples[0].label, 2);
        assert_eq!(es.samples[1].tags, vec![0, 1]);
        let tok = Tokenizer::new(default_vocab(), 300);
        let rows = es.framed_rows(&tok, 16).unwrap();
        assert_eq!(rows[0].len(), 16);
        assert_eq!(rows[0][0], tok.vocab.cls);
    }
}
