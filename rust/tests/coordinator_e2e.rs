//! End-to-end coordinator integration over real AOT artifacts.
//!
//! Uses whatever timing artifacts `make artifacts` produced (the quick
//! subset is enough). Covers: concurrent submission, completion of every
//! request, slot accounting, deadline behaviour with partial groups,
//! graceful shutdown, and the TCP server protocol.
//!
//! Each test SKIPS (passes with a notice) when artifacts or the PJRT
//! backend are unavailable — the artifact-free serving tests live in
//! tests/engine_api.rs and always run.

use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::server::{handle_line, Server, ServerConfig};
use datamux::coordinator::{EngineBuilder, SlotPolicy, Submit};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ArtifactMeta, LoadedModel,
                       ModelRuntime};
use datamux::workload::{closed_loop, RandomWorkload};

/// Load the smallest N>1 timing artifact, or None (skip) when the
/// artifacts or the PJRT backend are missing in this environment.
fn load_any_mux() -> Option<(ArtifactMeta, LoadedModel)> {
    let manifest = match ArtifactManifest::load(default_artifacts_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
    };
    let meta = manifest
        .artifacts
        .iter()
        .filter(|a| !a.trained && a.n_mux > 1)
        .min_by_key(|a| (a.d_model, a.n_mux))?
        .clone();
    let rt = match ModelRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT unavailable: {e:#}");
            return None;
        }
    };
    match rt.load(&meta) {
        Ok(model) => Some((meta, model)),
        Err(e) => {
            eprintln!("skipping: artifact load failed: {e:#}");
            None
        }
    }
}

#[test]
fn serves_concurrent_requests_without_loss() {
    let Some((meta, model)) = load_any_mux() else { return };
    let n_classes = meta.n_classes;
    let coord = Arc::new(EngineBuilder::new().max_wait_ms(2).build(model).unwrap());

    let mut w = RandomWorkload::new(42, 200, meta.seq_len - 4);
    let rows: Vec<Vec<i32>> =
        (0..64).map(|_| w.framed_row(&coord.tokenizer, meta.seq_len)).collect();
    let rows = Arc::new(rows);
    let report = closed_loop(&coord, &rows, 4, 32);
    assert_eq!(report.completed, 4 * 32, "every request completed");

    let c = coord.counters();
    assert_eq!(c.submitted, 128);
    assert_eq!(c.completed, 128);
    assert!(c.groups_executed > 0);
    // sanity on response contents via one more request
    let h = coord.submit_framed(rows[0].clone()).unwrap();
    let r = h.wait().unwrap();
    assert_eq!(r.logits.len(), n_classes);
    assert!(r.slot < meta.n_mux);
    assert!(r.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn partial_group_ships_at_deadline() {
    let Some((meta, model)) = load_any_mux() else { return };
    let coord = EngineBuilder::new().max_wait_ms(10).build(model).unwrap();
    // one lone request must still be answered (padded group)
    let mut w = RandomWorkload::new(7, 200, meta.seq_len - 4);
    let row = w.framed_row(&coord.tokenizer, meta.seq_len);
    let t0 = std::time::Instant::now();
    let h = coord.submit_framed(row).unwrap();
    let r = h.wait_timeout(Duration::from_secs(30)).expect("deadline flush").unwrap();
    assert!(t0.elapsed() >= Duration::from_millis(9), "waited for peers first");
    assert_eq!(r.slot, 0, "Fill policy: lone request sits in slot 0");
    let padded = coord.counters().slots_padded;
    assert_eq!(padded as usize, meta.batch * meta.n_mux - 1);
}

#[test]
fn rotate_policy_spreads_slots() {
    let Some((meta, model)) = load_any_mux() else { return };
    let coord = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(1)
            .slot_policy(SlotPolicy::RotateOffset)
            .build(model)
            .unwrap(),
    );
    let mut w = RandomWorkload::new(9, 200, meta.seq_len - 4);
    let mut slots_seen = std::collections::HashSet::new();
    for _ in 0..(meta.n_mux * 4) {
        let row = w.framed_row(&coord.tokenizer, meta.seq_len);
        let h = coord.submit_framed(row).unwrap();
        slots_seen.insert(h.wait().unwrap().slot);
    }
    // sequential lone requests under RotateOffset must not all pin slot 0
    assert!(slots_seen.len() > 1, "rotation should spread slots: {slots_seen:?}");
}

#[test]
fn shutdown_completes_inflight_requests() {
    let Some((meta, model)) = load_any_mux() else { return };
    let coord = EngineBuilder::new().max_wait_ms(50).build(model).unwrap();
    let mut w = RandomWorkload::new(11, 200, meta.seq_len - 4);
    let handles: Vec<_> = (0..5)
        .map(|_| {
            let row = w.framed_row(&coord.tokenizer, meta.seq_len);
            coord.submit_framed(row).unwrap()
        })
        .collect();
    let batches = coord.shutdown(); // must flush the waiting partial batch
    assert!(batches >= 1);
    for h in handles {
        let r = h.wait_timeout(Duration::from_secs(5)).expect("fulfilled");
        assert!(r.is_ok(), "in-flight requests complete on shutdown: {r:?}");
    }
}

#[test]
fn tcp_server_line_protocol() {
    use std::io::{BufRead, BufReader, Write};
    let Some((meta, model)) = load_any_mux() else { return };
    let coord = Arc::new(EngineBuilder::new().max_wait_ms(1).build(model).unwrap());

    // protocol unit (no socket)
    let reply = handle_line("CLS t1 t2 t3", &*coord).unwrap();
    assert!(reply.starts_with("OK "), "{reply}");
    let reply = handle_line("BOGUS x", &*coord).unwrap();
    assert!(reply.starts_with("ERR"), "{reply}");
    let reply = handle_line("CLS hello world", &*coord).unwrap();
    assert!(reply.starts_with("ERR"), "unknown words must ERR: {reply}");
    let stats = handle_line("STATS", &*coord).unwrap();
    assert!(stats.contains("submitted="), "{stats}");
    let _ = meta;

    // over a real socket
    let server = Server::start(
        coord.clone(),
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: 4, ..Default::default() },
    )
    .unwrap();
    let mut stream = std::net::TcpStream::connect(server.local_addr).unwrap();
    stream.write_all(b"CLS t4 t5\nQUIT\n").unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    server.stop();
}
