//! Engine API integration tests over the deterministic `FakeBackend` —
//! no AOT artifacts, no PJRT. Cover the unified `Submit` trait, typed
//! submit errors, deadline handling, worker-death recovery, the
//! shared-queue work-stealing router (lane death, pull-gate dispatch,
//! no-reject-while-capacity), and the TCP server (wire protocol v1 +
//! v2, pipelined) with a `MuxRouter` behind it.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use datamux::coordinator::server::{Server, ServerConfig};
use datamux::runtime::InferenceBackend;
use datamux::util::json::Json;
use datamux::{
    EngineBuilder, EngineError, FakeBackend, InferenceRequest, MuxCoordinator, MuxRouter, Submit,
    SubmitError,
};

const SEQ_LEN: usize = 8;
const N_CLASSES: usize = 3;

fn fake_cls(n_mux: usize) -> Arc<FakeBackend> {
    Arc::new(FakeBackend::new("cls", n_mux, 1, SEQ_LEN, N_CLASSES))
}

fn cls_engine(max_wait_ms: u64) -> Arc<MuxCoordinator> {
    Arc::new(
        EngineBuilder::new()
            .max_wait_ms(max_wait_ms)
            .build_backend(fake_cls(2))
            .unwrap(),
    )
}

/// A framed row `[CLS] t<k> [SEP] pad..` and the class the fake predicts.
fn framed_row(k: i32) -> (Vec<i32>, usize) {
    let mut row = vec![0i32; SEQ_LEN];
    row[0] = 1; // [CLS]
    row[1] = 44 + k; // t<k>
    row[2] = 2; // [SEP]
    let expected = FakeBackend::expected_class(&row, N_CLASSES);
    (row, expected)
}

#[test]
fn typed_submit_errors_are_distinct() {
    let coord = cls_engine(0);
    // over the model max: typed TooLong, never a silent truncation
    match coord.submit(InferenceRequest::classify_framed(vec![1; SEQ_LEN + 2])).err() {
        Some(SubmitError::TooLong { got, max }) => {
            assert_eq!((got, max), (SEQ_LEN + 2, SEQ_LEN));
        }
        other => panic!("expected TooLong, got {other:?}"),
    }
    // empty frame: BadFrame
    match coord.submit(InferenceRequest::classify_framed(Vec::new())).err() {
        Some(SubmitError::BadFrame { expected, got }) => {
            assert_eq!((expected, got), (SEQ_LEN, 0));
        }
        other => panic!("expected BadFrame, got {other:?}"),
    }
    // short unpadded frames are admissible now (bucketed admission)
    let h = coord.submit(InferenceRequest::classify_framed(vec![1, 45, 2])).unwrap();
    assert!(h.wait().is_ok());
    // tokenize: unknown word
    match coord.submit(InferenceRequest::classify_text("hello world")).err() {
        Some(SubmitError::Tokenize(_)) => {}
        other => panic!("expected Tokenize, got {other:?}"),
    }
    // wrong task: tag request against a cls model
    match coord.submit(InferenceRequest::tag_text("t1 t2")).err() {
        Some(SubmitError::WrongTask { .. }) => {}
        other => panic!("expected WrongTask, got {other:?}"),
    }
}

#[test]
fn responses_route_back_to_their_requests() {
    let coord = cls_engine(1);
    let mut handles = Vec::new();
    for i in 0..40 {
        let (row, expected) = framed_row(i % 100);
        handles.push((expected, coord.submit_framed(row).unwrap()));
    }
    for (expected, h) in handles {
        let r = h.wait().expect("response");
        assert_eq!(r.pred_class(), expected, "demux must route to the right caller");
        assert!(r.slot < 2);
    }
    let c = coord.counters();
    assert_eq!(c.submitted, 40);
    assert_eq!(c.completed, 40);
}

#[test]
fn submit_text_through_trait_matches_framed() {
    let coord = cls_engine(0);
    let framed = coord.tokenizer.encode_framed(&["t1 t2", "t3"], SEQ_LEN).unwrap();
    let expected = FakeBackend::expected_class(&framed, N_CLASSES);
    let h = coord.submit_text(&["t1 t2", "t3"]).unwrap();
    assert_eq!(h.wait().unwrap().pred_class(), expected);
}

#[test]
fn expired_requests_fail_engine_side_with_deadline() {
    // each execution takes 400ms; the first request ships alone (its
    // batch forms before the others are submitted), executes in time,
    // and the two submitted while the worker is busy expire at batch
    // assembly (their 200ms deadline passes during the first execution)
    let coord = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(0)
            .build_backend(Arc::new(
                FakeBackend::new("cls", 2, 1, SEQ_LEN, N_CLASSES)
                    .with_delay(Duration::from_millis(400)),
            ))
            .unwrap(),
    );
    let deadline = Duration::from_millis(200);
    let mut handles = Vec::new();
    for i in 0..3 {
        let (row, _) = framed_row(i);
        let req = InferenceRequest::classify_framed(row).with_deadline(deadline);
        handles.push(coord.submit(req).unwrap());
        if i == 0 {
            // let the first batch ship before queueing the rest: the
            // wave-draining batcher would otherwise co-mux request 1
            // into the first execution and serve it in time
            std::thread::sleep(Duration::from_millis(100));
        }
    }
    let results: Vec<_> = handles
        .iter()
        .map(|h| h.wait_timeout(Duration::from_secs(10)).expect("fulfilled"))
        .collect();
    assert!(results[0].is_ok(), "first request executes before its deadline: {results:?}");
    for r in &results[1..] {
        assert_eq!(*r, Err(EngineError::DeadlineExceeded), "{results:?}");
    }
    assert_eq!(coord.counters().expired, 2);

    // client-side: wait_deadline gives up at the deadline even though
    // the engine answers later
    let (row, _) = framed_row(9);
    let h = coord
        .submit(InferenceRequest::classify_framed(row).with_deadline(Duration::from_millis(50)))
        .unwrap();
    assert_eq!(h.wait_deadline(), Err(EngineError::DeadlineExceeded));
}

#[test]
fn worker_death_fails_pending_instead_of_hanging() {
    let coord = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(0)
            .build_backend(Arc::new(
                FakeBackend::new("cls", 2, 1, SEQ_LEN, N_CLASSES).failing_after(1),
            ))
            .unwrap(),
    );
    // first execution succeeds
    let (row, expected) = framed_row(1);
    let h = coord.submit_framed(row).unwrap();
    assert_eq!(h.wait().expect("first execution ok").pred_class(), expected);

    // everything after the backend starts failing is *answered*, never
    // stranded: WorkerFailed for executed batches, Shutdown once the
    // poisoned intake closes
    let mut accepted = Vec::new();
    let mut saw_shutdown_submit = false;
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(5) {
        let (row, _) = framed_row(2);
        match coord.submit_framed(row) {
            Ok(h) => accepted.push(h),
            Err(SubmitError::Shutdown) => {
                saw_shutdown_submit = true;
                break;
            }
            Err(e) => panic!("unexpected submit error: {e:?}"),
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(saw_shutdown_submit, "intake must be poisoned after worker failure");
    assert!(!accepted.is_empty());
    for h in accepted {
        let r = h.wait_timeout(Duration::from_secs(5)).expect("no caller may hang");
        match r {
            Err(EngineError::WorkerFailed(_)) | Err(EngineError::Shutdown) => {}
            other => panic!("expected a failure outcome, got {other:?}"),
        }
    }
}

#[test]
fn try_submit_distinguishes_queue_full_from_shutdown() {
    let coord = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(0)
            .queue_cap(1)
            .build_backend(Arc::new(
                FakeBackend::new("cls", 2, 1, SEQ_LEN, N_CLASSES)
                    .with_delay(Duration::from_millis(100)),
            ))
            .unwrap(),
    );
    let mut accepted = Vec::new();
    let mut saw_full = false;
    for i in 0..64 {
        let (row, _) = framed_row(i % 10);
        match coord.try_submit_framed(row) {
            Ok(h) => accepted.push(h),
            Err(SubmitError::QueueFull) => {
                saw_full = true;
                break;
            }
            Err(e) => panic!("unexpected: {e:?}"),
        }
    }
    assert!(saw_full, "tiny queue + slow backend must report QueueFull");

    coord.close_intake();
    let (row, _) = framed_row(1);
    assert_eq!(coord.try_submit_framed(row.clone()).err(), Some(SubmitError::Shutdown));
    assert_eq!(coord.submit_framed(row).err(), Some(SubmitError::Shutdown));

    for h in accepted {
        assert!(h.wait_timeout(Duration::from_secs(10)).expect("fulfilled").is_ok());
    }
}

#[test]
fn router_serves_bursts_and_aggregates_stats() {
    let lanes: Vec<Arc<dyn InferenceBackend>> = vec![fake_cls(2), fake_cls(8)];
    let router = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(1)
            .exec_time_us(10_000.0)
            .build_router_backends(lanes)
            .unwrap(),
    );
    assert_eq!(router.seq_len(), SEQ_LEN);
    let mut handles = Vec::new();
    for i in 0..64 {
        let (row, expected) = framed_row(i % 30);
        handles.push((expected, router.submit_framed(row).unwrap()));
    }
    for (expected, h) in handles {
        assert_eq!(h.wait().expect("response").pred_class(), expected);
    }
    let c = router.counters();
    assert_eq!(c.submitted, 64, "router counters aggregate across lanes");
    assert_eq!(c.completed, 64);
    assert!(router.latency().count >= 64);
    assert_eq!(router.queue_depth(), 0);
}

/// Regression: the per-arrival router herded traffic onto a dead lane
/// forever (it kept answering `Shutdown` while a healthy sibling sat
/// idle). With shared-queue work-stealing dispatch, killing one lane's
/// backend mid-burst must lose nothing: the dead lane's unexecuted
/// waves return to the shared queue, the survivor completes them, and
/// `Shutdown` never appears while a lane is alive.
#[test]
fn router_lane_death_mid_burst_steals_work_to_survivor() {
    let backends: Vec<Arc<dyn InferenceBackend>> = vec![
        // healthy small lane: 2 requests per 5ms execution
        Arc::new(
            FakeBackend::new("cls", 2, 1, SEQ_LEN, N_CLASSES)
                .with_delay(Duration::from_millis(5)),
        ),
        // large lane dies on its first execution
        Arc::new(FakeBackend::new("cls", 8, 1, SEQ_LEN, N_CLASSES).failing_after(0)),
    ];
    let router = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(1)
            .queue_cap(512)
            .exec_time_us(5_000.0)
            .build_router_backends(backends)
            .unwrap(),
    );
    let n = 160;
    let mut handles = Vec::new();
    for i in 0..n {
        let (row, expected) = framed_row(i as i32 % 30);
        handles.push((expected, router.submit_framed(row).unwrap()));
    }
    let (mut ok, mut failed) = (0usize, 0usize);
    for (expected, h) in handles {
        match h.wait_timeout(Duration::from_secs(60)).expect("no request may be stranded") {
            Ok(r) => {
                assert_eq!(r.pred_class(), expected, "stolen work still demuxes correctly");
                ok += 1;
            }
            Err(EngineError::WorkerFailed(_)) => failed += 1,
            Err(e) => panic!("got {e:?} — Shutdown is only legal once ALL lanes are dead"),
        }
    }
    assert_eq!(ok + failed, n, "every request answered");
    assert!(
        failed <= 8,
        "only the one failed execution may fail its batch, got {failed}"
    );
    // lane health is visible and correct: N=8 dead, N=2 still serving.
    // (the dead flag is set by the worker thread just after it answers
    // the failed batch, so give it a moment to land)
    let t0 = Instant::now();
    while router.live_lanes() > 1 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(5));
    }
    let status = router.lane_status();
    let dead = status.iter().find(|l| l.n_mux == 8).expect("N=8 lane listed");
    let alive = status.iter().find(|l| l.n_mux == 2).expect("N=2 lane listed");
    assert!(!dead.alive, "failed lane must be marked dead: {status:?}");
    assert!(alive.alive, "healthy lane must stay alive: {status:?}");
    assert_eq!(router.live_lanes(), 1);
    // the dead lane is never routed to again: new submissions keep working
    let (row, expected) = framed_row(3);
    let h = router.submit_framed(row).unwrap();
    assert_eq!(h.wait().expect("survivor serves new traffic").pred_class(), expected);
}

/// Pins the shared-queue admission invariant: a burst up to the
/// router's `queue_cap` is never rejected, regardless of which lanes
/// are busy — `try_submit` only answers `QueueFull` when the *router*
/// is full. (The per-arrival design fragmented capacity per lane and
/// could herd a burst onto one full lane while a sibling idled; the
/// *sustained-load* form of that regression — no rejects at offered
/// loads below aggregate lane capacity — is gated by
/// `benches/router_scaling.rs`, where the old herding design fails.)
#[test]
fn try_submit_never_rejects_while_any_lane_has_capacity() {
    let backends: Vec<Arc<dyn InferenceBackend>> = vec![
        Arc::new(
            FakeBackend::new("cls", 2, 1, SEQ_LEN, N_CLASSES)
                .with_delay(Duration::from_millis(20)),
        ),
        Arc::new(
            FakeBackend::new("cls", 20, 1, SEQ_LEN, N_CLASSES)
                .with_delay(Duration::from_millis(20)),
        ),
    ];
    let router = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(1)
            .queue_cap(64)
            .build_router_backends(backends)
            .unwrap(),
    );
    let mut handles = Vec::new();
    for i in 0..60 {
        let (row, expected) = framed_row(i % 25);
        let h = router
            .try_submit_framed(row)
            .expect("a 60-deep burst must never be rejected by a 64-deep shared queue");
        handles.push((expected, h));
    }
    for (expected, h) in handles {
        let r = h.wait_timeout(Duration::from_secs(30)).expect("fulfilled").expect("ok");
        assert_eq!(r.pred_class(), expected);
    }
    assert_eq!(router.counters().rejected, 0, "zero rejects with spare capacity");
}

/// `Shutdown` is the router's answer only once every lane is dead; by
/// then every accepted request has been answered (never stranded).
#[test]
fn router_reports_shutdown_only_when_all_lanes_are_dead() {
    let backends: Vec<Arc<dyn InferenceBackend>> = vec![
        Arc::new(FakeBackend::new("cls", 2, 1, SEQ_LEN, N_CLASSES).failing_after(0)),
        Arc::new(FakeBackend::new("cls", 8, 1, SEQ_LEN, N_CLASSES).failing_after(0)),
    ];
    let router = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(0)
            .queue_cap(256)
            .build_router_backends(backends)
            .unwrap(),
    );
    let mut accepted = Vec::new();
    let mut saw_shutdown = false;
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(10) {
        let (row, _) = framed_row(1);
        match router.submit_framed(row) {
            Ok(h) => accepted.push(h),
            Err(SubmitError::Shutdown) => {
                saw_shutdown = true;
                break;
            }
            Err(e) => panic!("unexpected submit error: {e:?}"),
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(saw_shutdown, "with every lane dead the router must answer Shutdown");
    assert_eq!(router.live_lanes(), 0);
    assert!(router.lane_status().iter().all(|l| !l.alive), "{:?}", router.lane_status());
    assert!(!accepted.is_empty());
    for h in accepted {
        match h.wait_timeout(Duration::from_secs(5)).expect("no caller may hang") {
            Err(EngineError::WorkerFailed(_)) | Err(EngineError::Shutdown) => {}
            other => panic!("expected a failure outcome, got {other:?}"),
        }
    }
}

#[test]
fn router_behind_server_pipelined_v2_and_v1_back_compat() {
    let lanes: Vec<Arc<dyn InferenceBackend>> = vec![fake_cls(2), fake_cls(8)];
    let router: Arc<MuxRouter> =
        Arc::new(EngineBuilder::new().max_wait_ms(1).build_router_backends(lanes).unwrap());
    let server = Server::start(
        router.clone(),
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: 4, ..Default::default() },
    )
    .unwrap();

    let stream = TcpStream::connect(server.local_addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // 12 pipelined requests on one connection: all written before any
    // reply is read; replies are correlated by client-chosen id
    let n = 12;
    let mut expected = std::collections::HashMap::new();
    let mut lines = String::new();
    for i in 0..n {
        let (_, pred) = framed_row(i as i32);
        expected.insert(format!("p{i}"), pred);
        lines.push_str(&format!(
            "{{\"id\":\"p{i}\",\"op\":\"classify\",\"text\":\"t{i}\"}}\n"
        ));
    }
    writer.write_all(lines.as_bytes()).unwrap();

    let mut seen = std::collections::HashMap::new();
    for _ in 0..n {
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = Json::parse(reply.trim()).expect("v2 replies are JSON");
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        let id = v.get("id").and_then(Json::as_str).expect("id echoed").to_string();
        let pred = v.get("pred").and_then(Json::as_usize).expect("pred");
        seen.insert(id, pred);
    }
    assert_eq!(seen, expected, "every id answered with its own prediction");

    // v1 still works on the same connection, against the same router
    writer.write_all(b"STATS\n").unwrap();
    let mut stats = String::new();
    reader.read_line(&mut stats).unwrap();
    assert!(stats.starts_with("OK submitted="), "{stats}");
    writer.write_all(b"CLS t1 t2\n").unwrap();
    let mut cls = String::new();
    reader.read_line(&mut cls).unwrap();
    assert!(cls.starts_with("OK "), "{cls}");

    writer.write_all(b"{\"op\":\"quit\"}\n").unwrap();
    server.stop();
    assert!(router.counters().completed >= n as u64 + 1);
}

#[test]
fn batch_submit_answers_on_one_line() {
    let coord = cls_engine(1);
    let server = Server::start(
        coord,
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: 2, ..Default::default() },
    )
    .unwrap();
    let stream = TcpStream::connect(server.local_addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer
        .write_all(
            b"{\"id\":\"B\",\"op\":\"batch\",\"items\":[\
              {\"op\":\"classify\",\"text\":\"t1\"},\
              {\"op\":\"classify\",\"text\":\"t2\"},\
              {\"op\":\"classify\",\"text\":\"nope\"}]}\n",
        )
        .unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = Json::parse(reply.trim()).unwrap();
    assert_eq!(v.get("id").and_then(Json::as_str), Some("B"));
    let results = v.get("results").and_then(Json::as_arr).expect("results array");
    assert_eq!(results.len(), 3);
    assert_eq!(results[0].get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(results[1].get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(results[2].get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(results[2].get("error").and_then(Json::as_str), Some("tokenize"));
    server.stop();
}

#[test]
fn server_stop_terminates_idle_connections() {
    let server = Server::start(
        cls_engine(0),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: 2,
            read_timeout: Duration::from_millis(100),
            ..Default::default()
        },
    )
    .unwrap();
    let stream = TcpStream::connect(server.local_addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    std::thread::sleep(Duration::from_millis(50)); // let handle_conn start
    let t0 = Instant::now();
    server.stop();
    // the idle connection's reader wakes on its read timeout, notices the
    // stop flag and closes: the client sees EOF well within bounds
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    let n = reader.read_line(&mut buf).expect("EOF, not a client-side timeout");
    assert_eq!(n, 0, "server must close the idle connection");
    assert!(t0.elapsed() < Duration::from_secs(3), "stop latency: {:?}", t0.elapsed());
}
