//! Lint fixture: raw lock primitives in coordinator scope (raw-lock).
//! Scanned by tests/lint_pass.rs, never compiled.

use std::sync::{Condvar, Mutex};

pub struct Queue {
    items: Mutex<Vec<u32>>,
    ready: Condvar,
}
