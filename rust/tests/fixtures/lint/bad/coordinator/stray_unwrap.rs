//! Lint fixture: serving-path panics (serving-panic).
//! Scanned by tests/lint_pass.rs, never compiled.

pub fn take(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn must(v: Option<u32>) -> u32 {
    v.expect("value missing")
}

pub fn boom() {
    panic!("fixture panic");
}
