//! Lint fixture: allocation inside a marker-armed function
//! (hot-path-alloc). Scanned by tests/lint_pass.rs, never compiled.

// lint: hot-path
pub fn accumulate(xs: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    out.extend_from_slice(xs);
    out
}
