//! Lint fixture: unsafe without a SAFETY justification (unsafe-safety),
//! in a file absent from the pinned inventory (unsafe-inventory).
//! Scanned by tests/lint_pass.rs, never compiled.

pub fn read_first(p: *const u32) -> u32 {
    unsafe { *p }
}
