//! Lint fixture: a clean coordinator file — tracked locks only, no
//! serving-path panics. Scanned by tests/lint_pass.rs, never compiled.

use crate::util::sync::{rank, TrackedMutex};

pub struct Gate {
    inner: TrackedMutex<u32>,
}

impl Gate {
    pub fn new() -> Gate {
        Gate { inner: TrackedMutex::new("fixture.gate", rank::NONE, 0) }
    }

    pub fn bump(&self) -> u32 {
        let mut v = self.inner.lock();
        *v += 1;
        *v
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_test_code() {
        let v = Some(1).unwrap();
        assert_eq!(v, 1);
    }
}
