//! Lint fixture: a marker-armed function that stays allocation-free.
//! Scanned by tests/lint_pass.rs, never compiled.

// lint: hot-path
pub fn saxpy(a: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * *xi;
    }
}
