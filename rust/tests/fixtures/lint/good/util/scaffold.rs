//! Lint fixture: util scope — unwrap stays legal outside serving code.
//! Scanned by tests/lint_pass.rs, never compiled.

pub fn parse(s: &str) -> u32 {
    s.parse().unwrap()
}
