//! Hot-path invariant tests over the full engine (FakeBackend, no
//! artifacts): zero-copy demux sharing, allocation-free steady state,
//! cross-batch reuse safety at the system level, and the queue-wait /
//! wave accounting introduced with the batched intake.
//!
//! The buffer-poisoning property test lives next to the scheduler
//! (`coordinator::scheduler::tests`), where the scratch buffer is
//! directly reachable; these tests assert the same invariants through
//! the public `Submit` surface.

use std::sync::Arc;
use std::time::Duration;

use datamux::{EngineBuilder, FakeBackend, MuxCoordinator, Submit};

const N_MUX: usize = 4;
const BATCH: usize = 2;
const SEQ_LEN: usize = 8;
const N_CLASSES: usize = 5;

fn engine(max_wait_ms: u64) -> Arc<MuxCoordinator> {
    Arc::new(
        EngineBuilder::new()
            .max_wait_ms(max_wait_ms)
            .queue_cap(4096)
            .build_backend(Arc::new(FakeBackend::new(
                "cls", N_MUX, BATCH, SEQ_LEN, N_CLASSES,
            )))
            .unwrap(),
    )
}

/// A framed row whose fake-model class is distinct per `k`.
fn row(k: usize) -> (Vec<i32>, usize) {
    let mut r = vec![0i32; SEQ_LEN];
    r[0] = 1; // [CLS]
    r[1] = 44 + (k % 200) as i32;
    r[2] = 2; // [SEP]
    (r.clone(), FakeBackend::expected_class(&r, N_CLASSES))
}

#[test]
fn responses_of_one_batch_share_a_single_logits_buffer() {
    // max_wait far above any scheduler stall: the batch still ships the
    // moment all capacity requests arrive, so this costs no time
    let coord = engine(2_000);
    let capacity = N_MUX * BATCH;
    // saturate exactly one execution; the generous max_wait keeps all
    // requests in one batch
    let handles: Vec<_> = (0..capacity)
        .map(|k| {
            let (r, want) = row(k);
            (want, coord.submit_framed(r).unwrap())
        })
        .collect();
    let responses: Vec<_> = handles
        .into_iter()
        .map(|(want, h)| {
            let r = h.wait().expect("response");
            assert_eq!(r.pred_class(), want, "demux routed to the right caller");
            r
        })
        .collect();
    let first = &responses[0];
    assert!(
        responses.iter().all(|r| r.group == first.group),
        "expected one batch, got groups {:?}",
        responses.iter().map(|r| r.group).collect::<Vec<_>>()
    );
    for r in &responses[1..] {
        assert!(
            first.logits.same_buffer(&r.logits),
            "steady-state demux must share, not copy"
        );
    }
    // every view is alive, so the batch buffer has one owner per response
    assert!(first.logits.shared_count() >= capacity);
    // logits are views of the right slices, still individually correct
    for r in &responses {
        assert_eq!(r.logits.len(), N_CLASSES);
        assert!(r.logits.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn reused_buffers_never_leak_across_batches() {
    // long-lived engine; the worker reuses one scratch buffer and the
    // template across every batch. 40 waves of distinct contents: any
    // stale token from a previous batch flips a fake-model prediction.
    let coord = engine(1);
    for wave in 0..40 {
        let handles: Vec<_> = (0..N_MUX * BATCH)
            .map(|k| {
                let (r, want) = row(wave * 31 + k);
                (want, coord.submit_framed(r).unwrap())
            })
            .collect();
        for (want, h) in handles {
            let r = h
                .wait_timeout(Duration::from_secs(10))
                .expect("fulfilled")
                .expect("response");
            assert_eq!(r.pred_class(), want, "wave {wave}: cross-batch leak");
        }
    }
    let c = coord.counters();
    assert_eq!(c.completed, 40 * (N_MUX * BATCH) as u64);
    // allocation-free steady state: the worker scratch is pre-sized, so
    // serving never grew it
    assert_eq!(c.scratch_reallocs, 0, "scratch must never grow mid-serving");
}

/// Mixed-length serving keeps every hot-path invariant: per-bucket
/// worker scratches never grow (`scratch_reallocs == 0`), demux routes
/// every unpadded row back to its own caller, and the padding-waste
/// counter reflects bucket-length (not max-length) padding.
#[test]
fn bucketed_mixed_lengths_keep_scratch_invariant_and_route_correctly() {
    let coord = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(1)
            .queue_cap(4096)
            .buckets(vec![2, 4])
            .build_backend(Arc::new(FakeBackend::new(
                "cls", N_MUX, BATCH, SEQ_LEN, N_CLASSES,
            )))
            .unwrap(),
    );
    // unpadded rows of every length 1..=SEQ_LEN, repeated across waves
    let mut total = 0u64;
    for wave in 0..20 {
        let handles: Vec<_> = (1..=SEQ_LEN)
            .map(|len| {
                let mut r = vec![0i32; len];
                r[0] = 1; // [CLS]
                if len > 1 {
                    r[1] = 44 + ((wave * 17 + len) % 200) as i32;
                }
                let want = FakeBackend::expected_class(&r, N_CLASSES);
                (want, coord.submit_framed(r).unwrap())
            })
            .collect();
        for (want, h) in handles {
            let r = h
                .wait_timeout(Duration::from_secs(10))
                .expect("fulfilled")
                .expect("response");
            assert_eq!(r.pred_class(), want, "wave {wave}: bucketed demux crossed wires");
            total += 1;
        }
    }
    let c = coord.counters();
    assert_eq!(c.completed, total);
    assert_eq!(c.scratch_reallocs, 0, "per-bucket scratch must never grow mid-serving");
    assert!(c.tokens_padded > 0, "partial waves + short rows leave padding");
    // the per-bucket split accounts for every request
    let lanes = coord.lane_status();
    let entries: u64 = lanes[0].buckets.iter().map(|b| b.entries).sum();
    assert_eq!(entries, total);
    assert_eq!(
        lanes[0].buckets.iter().map(|b| b.seq_len).collect::<Vec<_>>(),
        vec![2, 4, SEQ_LEN]
    );
    assert!(lanes[0].buckets.iter().all(|b| b.waves > 0), "{:?}", lanes[0].buckets);
}

#[test]
fn wave_and_queue_wait_accounting_is_populated() {
    let coord = engine(2);
    let total = 3 * N_MUX * BATCH;
    let handles: Vec<_> = (0..total).map(|k| coord.submit_framed(row(k).0).unwrap()).collect();
    for h in handles {
        h.wait().expect("response");
    }
    let c = coord.counters();
    assert!(c.intake_waves >= 1, "batcher must tally its drains");
    assert!(
        c.intake_waves <= c.submitted,
        "waves cannot exceed requests: {} > {}",
        c.intake_waves,
        c.submitted
    );
    let qw = coord.queue_wait();
    assert_eq!(qw.count, total as u64, "every request records queue wait");
    // queue wait is the submit -> batch-formed component, so it is
    // bounded by e2e latency
    assert!(qw.p50_ns <= coord.latency().max_ns.max(1));
}
