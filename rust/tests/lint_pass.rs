//! The lint pass's own gate: every seeded fixture violation fires, and
//! the live source tree comes back clean.
//!
//! Fixtures live under `tests/fixtures/lint/{bad,good}/` — they mirror
//! the `src/` directory layout (the rule scopes key on it) and are
//! scanned by [`datamux::analysis::lint_dir`], never compiled.

use std::path::PathBuf;

use datamux::analysis::{lint_dir, Rule, Violation};

fn fixture(which: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/lint").join(which)
}

fn lint_fixture(which: &str) -> Vec<Violation> {
    lint_dir(&fixture(which)).expect("fixture tree scans").violations
}

fn fired(violations: &[Violation], file: &str, rule: Rule) -> bool {
    violations.iter().any(|v| v.file == file && v.rule == rule)
}

#[test]
fn seeded_violations_all_fire() {
    let v = lint_fixture("bad");
    assert!(fired(&v, "coordinator/raw_lock.rs", Rule::RawLock), "{v:#?}");
    assert!(fired(&v, "coordinator/stray_unwrap.rs", Rule::ServingPanic), "{v:#?}");
    assert!(fired(&v, "runtime/missing_safety.rs", Rule::UnsafeSafety), "{v:#?}");
    assert!(fired(&v, "runtime/missing_safety.rs", Rule::UnsafeInventory), "{v:#?}");
    assert!(fired(&v, "hot_alloc.rs", Rule::HotPathAlloc), "{v:#?}");
}

#[test]
fn unwrap_expect_and_panic_each_fire() {
    let v = lint_fixture("bad");
    let hits: Vec<&Violation> =
        v.iter().filter(|x| x.file == "coordinator/stray_unwrap.rs").collect();
    assert_eq!(hits.len(), 3, "unwrap, expect and panic each flagged once: {hits:#?}");
    assert!(hits.iter().all(|x| x.rule == Rule::ServingPanic), "{hits:#?}");
}

#[test]
fn raw_mutex_and_condvar_both_flagged() {
    let v = lint_fixture("bad");
    let locks: Vec<&str> = v
        .iter()
        .filter(|x| x.file == "coordinator/raw_lock.rs")
        .map(|x| x.message.as_str())
        .collect();
    assert!(locks.iter().any(|m| m.contains("`Mutex`")), "{locks:?}");
    assert!(locks.iter().any(|m| m.contains("`Condvar`")), "{locks:?}");
}

#[test]
fn good_tree_is_clean() {
    let v = lint_fixture("good");
    assert!(v.is_empty(), "{v:#?}");
}

#[test]
fn live_tree_is_clean() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_dir(&src).expect("src tree scans");
    assert!(
        report.violations.is_empty(),
        "datamux lint must pass on the live tree:\n{}",
        report.violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
    );
    assert!(report.files_scanned >= 40, "only {} files scanned", report.files_scanned);
}
