//! Native-backend integration: the artifact-free proptest against the
//! scalar reference, parity against real artifact blobs when they exist
//! (skip-with-notice otherwise), and an `engine_api`-style end-to-end
//! server run — TCP + wire protocol v2 over [`NativeBackend`] — proving
//! the whole stack serves real T-MUX math with zero artifacts and no
//! PJRT.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use datamux::coordinator::request::argmax;
use datamux::coordinator::scheduler::MuxTemplate;
use datamux::coordinator::server::{Server, ServerConfig};
use datamux::runtime::native::{reference, synthetic_meta, Precision, RawWeights};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, WeightsFile};
use datamux::tokenizer::{default_vocab, Tokenizer};
use datamux::util::json::Json;
use datamux::{EngineBuilder, InferenceBackend, NativeBackend, Submit};

/// Property: across random shapes, tasks and thread counts, the fused
/// native forward (mux → encoder → demux) agrees with the
/// straightforward unoptimized scalar reference within 1e-4.
#[test]
fn prop_native_forward_matches_scalar_reference() {
    datamux::util::proptest::check("native forward vs scalar reference", 8, |g| {
        let n_heads = [1usize, 2, 4][g.rng.below(3)];
        let d_model = n_heads * [4usize, 8][g.rng.below(2)];
        let n_layers = g.rng.range(1, 3);
        let n_mux = g.rng.range(1, 5);
        let batch = g.rng.range(1, 3);
        // up to input_len = 5 + 20 = 25: crosses the flash-attention
        // tile width (16), covering the multi-tile online-softmax path
        let seq_len = g.rng.range(3, 20);
        let n_classes = g.rng.range(2, 6);
        let task = if g.rng.below(2) == 0 { "cls" } else { "token" };
        let threads = if g.rng.below(2) == 0 { 1 } else { 3 };
        let seed = g.rng.next_u64();
        let meta =
            synthetic_meta(task, n_mux, batch, seq_len, d_model, n_layers, n_heads, n_classes);
        let raw = RawWeights::random(&meta, 2 * d_model, seed);
        let wf = WeightsFile::parse(raw.to_blob()).map_err(|e| e.to_string())?;
        let backend = NativeBackend::from_weights(meta.clone(), wf)
            .map_err(|e| e.to_string())?
            .with_threads(threads);
        let ids: Vec<i32> =
            (0..meta.ids_len()).map(|_| g.rng.below(meta.vocab_size) as i32).collect();
        let got = backend.run_ids(&ids).map_err(|e| e.to_string())?;
        let want = reference::forward(&raw, &meta, &ids).map_err(|e| e.to_string())?;
        if got.len() != want.len() {
            return Err(format!("output length {} != reference {}", got.len(), want.len()));
        }
        for i in 0..got.len() {
            let tol = 1e-4 * (1.0 + want[i].abs());
            if (got[i] - want[i]).abs() > tol {
                return Err(format!(
                    "task {task} d={d_model} h={n_heads} l={n_layers} n={n_mux} b={batch} \
                     threads={threads}: logit {i} fused {} vs reference {}",
                    got[i], want[i]
                ));
            }
        }
        Ok(())
    });
}

/// Property: the fused native forward at **every bucket length** of a
/// random model matches the scalar reference parameterized the same way
/// (`reference::forward_at`), within 1e-4, across tasks and thread
/// counts. This is the bucketed twin of the full-shape proptest above —
/// it pins the whole shape-polymorphic surface: runtime attention
/// shapes, positional-table prefixes, demux offsets, per-bucket arenas.
#[test]
fn prop_bucketed_native_forward_matches_scalar_reference_at_every_bucket() {
    datamux::util::proptest::check("bucketed native forward vs reference", 6, |g| {
        let n_heads = [1usize, 2][g.rng.below(2)];
        let d_model = n_heads * [4usize, 8][g.rng.below(2)];
        let n_layers = g.rng.range(1, 3);
        let n_mux = g.rng.range(1, 4);
        let batch = g.rng.range(1, 3);
        // buckets past the flash-attention tile width (16) exercise the
        // tile-tail path (li not divisible by ATTN_TILE) at every length
        let seq_len_max = g.rng.range(6, 18);
        let n_classes = g.rng.range(2, 5);
        let task = if g.rng.below(2) == 0 { "cls" } else { "token" };
        let threads = if g.rng.below(2) == 0 { 1 } else { 3 };
        let seed = g.rng.next_u64();
        let meta = synthetic_meta(
            task, n_mux, batch, seq_len_max, d_model, n_layers, n_heads, n_classes,
        );
        let raw = RawWeights::random(&meta, 2 * d_model, seed);
        let wf = WeightsFile::parse(raw.to_blob()).map_err(|e| e.to_string())?;
        let backend = NativeBackend::from_weights(meta.clone(), wf)
            .map_err(|e| e.to_string())?
            .with_threads(threads);
        // every bucket length of this model, not a sample
        for bucket in 1..=seq_len_max {
            let li = n_mux + bucket;
            let ids: Vec<i32> = (0..batch * n_mux * li)
                .map(|_| g.rng.below(meta.vocab_size) as i32)
                .collect();
            let got = backend.run_ids_at(&ids, bucket).map_err(|e| e.to_string())?;
            let want =
                reference::forward_at(&raw, &meta, bucket, &ids).map_err(|e| e.to_string())?;
            if got.len() != want.len() {
                return Err(format!(
                    "bucket {bucket}: output length {} != reference {}",
                    got.len(),
                    want.len()
                ));
            }
            for i in 0..got.len() {
                let tol = 1e-4 * (1.0 + want[i].abs());
                if (got[i] - want[i]).abs() > tol {
                    return Err(format!(
                        "task {task} d={d_model} h={n_heads} l={n_layers} n={n_mux} \
                         b={batch} threads={threads} bucket={bucket}: logit {i} fused {} \
                         vs reference {}",
                        got[i], want[i]
                    ));
                }
            }
        }
        // per-bucket arenas settle: a second pass over all buckets must
        // not materialize anything new
        let before = backend.arena_reallocs();
        for bucket in 1..=seq_len_max {
            let li = n_mux + bucket;
            let ids: Vec<i32> = vec![1; batch * n_mux * li];
            backend.run_ids_at(&ids, bucket).map_err(|e| e.to_string())?;
        }
        if backend.arena_reallocs() != before {
            return Err(format!(
                "arena grew after warmup: {} -> {}",
                before,
                backend.arena_reallocs()
            ));
        }
        Ok(())
    });
}

/// End-to-end over real math with zero artifacts: TCP server, wire
/// protocol v2, typed engine underneath, `NativeBackend` doing the
/// actual transformer forward. Requests are submitted lock-step so each
/// executes alone (slot 0 of an otherwise-empty group), which makes the
/// expected prediction computable by running the same tensor directly
/// through the backend.
#[test]
fn native_end_to_end_server_v2_with_zero_artifacts() {
    const SEQ: usize = 8;
    const NCLS: usize = 3;
    let backend = Arc::new(NativeBackend::random("cls", 4, 1, SEQ, 16, 1, 2, NCLS, 99).unwrap());
    let meta = backend.meta().clone();
    let tok = Tokenizer::new(default_vocab(), meta.vocab_size);
    let template = MuxTemplate::new(&meta, &tok);

    let expected_pred = |text: &str| -> usize {
        let framed = tok.encode_framed(&[text], SEQ).unwrap();
        let mut ids = Vec::new();
        template.stamp(&mut ids);
        let range = template.content_range(0, 0);
        ids[range].copy_from_slice(&framed);
        let out = backend.run_ids(&ids).unwrap();
        argmax(&out[..NCLS])
    };

    let engine = Arc::new(
        EngineBuilder::new().max_wait_ms(0).build_backend(backend.clone()).unwrap(),
    );
    let server = Server::start(
        engine.clone(),
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: 2, ..Default::default() },
    )
    .unwrap();
    let stream = TcpStream::connect(server.local_addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    for i in 0..6 {
        let text = format!("t{} t{}", i, i + 3);
        let want = expected_pred(&text);
        let line = format!("{{\"id\":\"q{i}\",\"op\":\"classify\",\"text\":\"{text}\"}}\n");
        writer.write_all(line.as_bytes()).unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = Json::parse(reply.trim()).expect("v2 replies are JSON");
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        let id = format!("q{i}");
        assert_eq!(v.get("id").and_then(Json::as_str), Some(id.as_str()));
        assert_eq!(
            v.get("pred").and_then(Json::as_usize),
            Some(want),
            "real math must round-trip the wire: {reply}"
        );
        assert_eq!(
            v.get("slot").and_then(Json::as_usize),
            Some(0),
            "a lone request fills slot 0: {reply}"
        );
    }
    // a repeated text must reproduce its prediction (deterministic math)
    let text = "t1 t4";
    let want = expected_pred(text);
    for r in 0..2 {
        let line = format!("{{\"id\":\"r{r}\",\"op\":\"classify\",\"text\":\"{text}\"}}\n");
        writer.write_all(line.as_bytes()).unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = Json::parse(reply.trim()).unwrap();
        assert_eq!(v.get("pred").and_then(Json::as_usize), Some(want), "{reply}");
    }
    // stats over the same connection, then shut down
    writer.write_all(b"{\"id\":\"s\",\"op\":\"stats\"}\n").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = Json::parse(reply.trim()).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
    writer.write_all(b"{\"op\":\"quit\"}\n").unwrap();
    server.stop();
    assert!(engine.counters().completed >= 8);
}

/// End-to-end bucketed serving over real math: a TCP server on a
/// native-backend engine with buckets {4, 8, 16}, driven by a
/// mixed-length workload. Pins: (a) per-request correctness at every
/// bucket against a hand-assembled single-slot execution of the same
/// backend, (b) zero rejects across the whole run, (c) v2 STATS
/// reporting per-bucket waves/entries and the padding-waste counter.
#[test]
fn bucketed_server_serves_mixed_lengths_with_zero_rejects() {
    const SEQ_MAX: usize = 16;
    const NCLS: usize = 3;
    let backend =
        Arc::new(NativeBackend::random("cls", 2, 1, SEQ_MAX, 16, 1, 2, NCLS, 7).unwrap());
    let meta = backend.meta().clone();
    let tok = Tokenizer::new(default_vocab(), meta.vocab_size);
    let bucket_lens = [4usize, 8, 16];
    let bucket_of = |len: usize| *bucket_lens.iter().find(|&&b| b >= len).unwrap();

    // oracle: run the same unpadded content alone (slot 0) through the
    // backend at its bucket's shape
    let expected_pred = |content: &[i32]| -> usize {
        let b = bucket_of(content.len());
        let template = MuxTemplate::for_bucket(&meta, &tok, b);
        let mut ids = Vec::new();
        template.stamp(&mut ids);
        let range = template.content_range(0, 0);
        ids[range][..content.len()].copy_from_slice(content);
        let out = backend.run_ids_at(&ids, b).unwrap();
        argmax(&out[..NCLS])
    };

    let engine = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(0)
            .buckets(vec![4, 8])
            .build_backend(backend.clone())
            .unwrap(),
    );
    assert_eq!(engine.buckets(), vec![4, 8, 16]);
    let server = Server::start(
        engine.clone(),
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: 2, ..Default::default() },
    )
    .unwrap();
    let stream = TcpStream::connect(server.local_addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // lock-step phase: one request per bucket class, correctness pinned
    let mut used_lens = Vec::new();
    for (i, body) in [1usize, 2, 5, 6, 11, 14].into_iter().enumerate() {
        let text: String =
            (0..body).map(|k| format!("t{}", (i * 7 + k) % 50)).collect::<Vec<_>>().join(" ");
        let content = tok.encode_framed_unpadded(&[&text], SEQ_MAX).unwrap();
        used_lens.push(content.len());
        let want = expected_pred(&content);
        let ids_json: Vec<String> = content.iter().map(|x| x.to_string()).collect();
        let line = format!(
            "{{\"id\":\"m{i}\",\"op\":\"classify\",\"ids\":[{}]}}\n",
            ids_json.join(",")
        );
        writer.write_all(line.as_bytes()).unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = Json::parse(reply.trim()).expect("v2 replies are JSON");
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        assert_eq!(
            v.get("pred").and_then(Json::as_usize),
            Some(want),
            "bucket {} must serve the same math as a direct call: {reply}",
            bucket_of(content.len())
        );
    }
    assert!(
        used_lens.iter().any(|&l| l <= 4)
            && used_lens.iter().any(|&l| l > 4 && l <= 8)
            && used_lens.iter().any(|&l| l > 8),
        "workload must cover all three buckets: {used_lens:?}"
    );

    // burst phase: pipeline mixed lengths, every one answered ok
    let n = 24;
    let mut lines = String::new();
    for i in 0..n {
        let body = 1 + (i * 5) % 13; // 1..=13 content tokens -> all buckets
        let text: String =
            (0..body).map(|k| format!("t{}", (i + k) % 50)).collect::<Vec<_>>().join(" ");
        lines.push_str(&format!("{{\"id\":\"b{i}\",\"op\":\"classify\",\"text\":\"{text}\"}}\n"));
    }
    writer.write_all(lines.as_bytes()).unwrap();
    let mut ok = 0;
    for _ in 0..n {
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = Json::parse(reply.trim()).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        ok += 1;
    }
    assert_eq!(ok, n, "zero rejects across the mixed-length burst");

    // stats phase: per-bucket waves visible, padding waste counted
    writer.write_all(b"{\"id\":\"s\",\"op\":\"stats\"}\n").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = Json::parse(reply.trim()).unwrap();
    let stats = v.get("stats").expect("stats object");
    assert_eq!(stats.get("rejected").and_then(Json::as_usize), Some(0), "{reply}");
    assert!(stats.get("tokens_padded").and_then(Json::as_usize).unwrap_or(0) > 0, "{reply}");
    let buckets = stats.get("buckets").and_then(Json::as_arr).expect("buckets array");
    assert_eq!(buckets.len(), 3, "{reply}");
    let entries: usize = buckets
        .iter()
        .map(|b| b.get("entries").and_then(Json::as_usize).unwrap_or(0))
        .sum();
    assert_eq!(entries, 6 + n, "every request tallied under its bucket: {reply}");
    for b in buckets {
        assert!(
            b.get("waves").and_then(Json::as_usize).unwrap_or(0) > 0,
            "all three buckets saw traffic: {reply}"
        );
    }

    writer.write_all(b"{\"op\":\"quit\"}\n").unwrap();
    server.stop();
    assert_eq!(engine.counters().completed, (6 + n) as u64);
}

/// When real artifacts exist, the native forward must reproduce the
/// python compile path's parity vectors from the same weights blobs.
/// Skips (passes with a notice) when artifacts are absent, and per
/// artifact when the config needs PJRT (ortho mux, retrieval).
#[test]
fn native_matches_artifact_parity_blobs() {
    let manifest = match ArtifactManifest::load(default_artifacts_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
    };
    let mut checked = 0usize;
    for meta in &manifest.artifacts {
        if meta.parity.is_none() {
            continue;
        }
        let backend = match NativeBackend::from_artifact(meta) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping {} (native: {e:#})", meta.name);
                continue;
            }
        };
        backend.verify_parity().unwrap_or_else(|e| panic!("{e}"));
        eprintln!("native parity OK: {}", meta.name);
        checked += 1;
    }
    if checked == 0 {
        eprintln!("skipping: no native-servable parity artifacts found");
    }
}

/// Property: the int8 quantized forward tracks the f32 forward across
/// random models and **every bucket length**. Two bounds, two twin
/// models per case:
///
/// * on the plain random model, the max absolute int8-vs-f32 logit
///   error stays within `0.08 * (1 + max |logit_f32|)` — quantization
///   noise scaled to the logit range;
/// * on a twin whose head biases are inflated (class margins dwarf
///   quantization noise, as a trained head's do), argmax predictions
///   agree ≥ 99.5% aggregated over the whole run.
///
/// The int8 backend loads a **DMUXW2** blob (`to_blob_q8`) while the
/// f32 backend loads the unchanged v1 blob — so this also pins that
/// both format revisions keep loading side by side.
#[test]
fn prop_int8_forward_tracks_f32_at_every_bucket() {
    let mut total = 0usize;
    let mut agree = 0usize;
    datamux::util::proptest::check("int8 vs f32 native forward", 6, |g| {
        let n_heads = [1usize, 2][g.rng.below(2)];
        let d_model = n_heads * 8;
        let n_layers = g.rng.range(1, 3);
        let n_mux = g.rng.range(1, 4);
        let batch = g.rng.range(1, 3);
        // past the flash-attention tile width so int8 QKV fusion is
        // exercised on the multi-tile path too
        let seq_len_max = g.rng.range(4, 17);
        let n_classes = g.rng.range(2, 5);
        let task = if g.rng.below(2) == 0 { "cls" } else { "token" };
        let seed = g.rng.next_u64();
        let meta = synthetic_meta(
            task, n_mux, batch, seq_len_max, d_model, n_layers, n_heads, n_classes,
        );
        let mut raw = RawWeights::random(&meta, 2 * d_model, seed);
        let build = |raw: &RawWeights, precision: Precision| -> Result<NativeBackend, String> {
            let blob = match precision {
                Precision::F32 => raw.to_blob(),
                Precision::Int8 => raw.to_blob_q8(),
            };
            let wf = WeightsFile::parse(blob).map_err(|e| e.to_string())?;
            NativeBackend::from_weights_prec(meta.clone(), wf, precision)
                .map_err(|e| e.to_string())
        };
        let bf = build(&raw, Precision::F32)?;
        let bq = build(&raw, Precision::Int8)?;
        for bucket in 1..=seq_len_max {
            let li = n_mux + bucket;
            let ids: Vec<i32> = (0..batch * n_mux * li)
                .map(|_| g.rng.below(meta.vocab_size) as i32)
                .collect();
            let lf = bf.run_ids_at(&ids, bucket).map_err(|e| e.to_string())?;
            let lq = bq.run_ids_at(&ids, bucket).map_err(|e| e.to_string())?;
            if lf.len() != lq.len() {
                return Err(format!(
                    "bucket {bucket}: int8 length {} != f32 {}",
                    lq.len(),
                    lf.len()
                ));
            }
            let allowed = 0.08 * (1.0 + lf.iter().fold(0.0f32, |m, x| m.max(x.abs())));
            for i in 0..lf.len() {
                if (lf[i] - lq[i]).abs() > allowed {
                    return Err(format!(
                        "task {task} d={d_model} l={n_layers} n={n_mux} b={batch} \
                         bucket={bucket}: logit {i} f32 {} vs int8 {} (allowed {allowed})",
                        lf[i], lq[i]
                    ));
                }
            }
        }
        // argmax twin: a trained head separates classes by margins far
        // above quantization noise — model that by inflating the head
        // biases, then require near-perfect prediction agreement
        for (name, _, data) in raw.tensors.iter_mut() {
            if name == "head_cls/b" || name == "head_token/b" {
                for v in data.iter_mut() {
                    *v = (g.rng.normal() * 55.0) as f32;
                }
            }
        }
        let bf = build(&raw, Precision::F32)?;
        let bq = build(&raw, Precision::Int8)?;
        for bucket in 1..=seq_len_max {
            let li = n_mux + bucket;
            let ids: Vec<i32> = (0..batch * n_mux * li)
                .map(|_| g.rng.below(meta.vocab_size) as i32)
                .collect();
            let lf = bf.run_ids_at(&ids, bucket).map_err(|e| e.to_string())?;
            let lq = bq.run_ids_at(&ids, bucket).map_err(|e| e.to_string())?;
            for (gf, gq) in lf.chunks_exact(n_classes).zip(lq.chunks_exact(n_classes)) {
                total += 1;
                if argmax(gf) == argmax(gq) {
                    agree += 1;
                }
            }
        }
        Ok(())
    });
    assert!(total > 0, "the property must have scored predictions");
    let ratio = agree as f64 / total as f64;
    assert!(
        ratio >= 0.995,
        "int8 argmax agreement {agree}/{total} = {ratio:.4} < 0.995"
    );
}

/// Same blob, same ids, different thread counts: bitwise identical —
/// row banding must never change the arithmetic.
#[test]
fn thread_count_does_not_change_results() {
    let meta = synthetic_meta("token", 3, 2, 6, 16, 2, 4, 5);
    let raw = RawWeights::random(&meta, 32, 1234);
    let make = |threads: usize| {
        NativeBackend::from_weights(meta.clone(), WeightsFile::parse(raw.to_blob()).unwrap())
            .unwrap()
            .with_threads(threads)
    };
    let ids: Vec<i32> = (0..meta.ids_len() as i32).map(|i| (i * 7) % 200).collect();
    let serial = make(1).run_ids(&ids).unwrap();
    for threads in [2, 4] {
        assert_eq!(serial, make(threads).run_ids(&ids).unwrap(), "threads={threads}");
    }
}
