//! Integration: every artifact in the manifest must load, execute, and
//! bit-reproduce the python compile path's parity vectors.
//!
//! Skips (passes with a notice) when artifacts or PJRT are unavailable.
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};

fn setup() -> Option<(ArtifactManifest, ModelRuntime)> {
    let manifest = match ArtifactManifest::load(default_artifacts_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
    };
    match ModelRuntime::cpu() {
        Ok(rt) => Some((manifest, rt)),
        Err(e) => {
            eprintln!("skipping: PJRT unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn all_artifacts_load_and_match_python() {
    let Some((manifest, rt)) = setup() else { return };
    assert!(!manifest.artifacts.is_empty());
    for meta in &manifest.artifacts {
        let model = rt.load(meta).expect("load");
        if meta.parity.is_some() {
            model.verify_parity().unwrap_or_else(|e| panic!("{e}"));
        } else {
            // still must execute with zeros and produce the right shape
            let ids = vec![0i32; meta.ids_len()];
            let out = model.run_ids(&ids).expect("run");
            assert_eq!(out.len(), meta.output_len());
        }
    }
}

#[test]
fn repeated_execution_is_deterministic() {
    let Some((manifest, rt)) = setup() else { return };
    let meta = &manifest.artifacts[0];
    let model = rt.load(meta).expect("load");
    let ids: Vec<i32> = (0..meta.ids_len() as i32).map(|i| i % 40).collect();
    let a = model.run_ids(&ids).expect("run a");
    let b = model.run_ids(&ids).expect("run b");
    assert_eq!(a, b, "weights buffers must be reusable across calls");
}
