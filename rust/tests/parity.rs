//! Integration: every artifact in the manifest must load, execute, and
//! bit-reproduce the python compile path's parity vectors.
use datamux::runtime::{ArtifactManifest, ModelRuntime, default_artifacts_dir};

#[test]
fn all_artifacts_load_and_match_python() {
    let manifest = ArtifactManifest::load(default_artifacts_dir()).expect("manifest");
    assert!(!manifest.artifacts.is_empty());
    let rt = ModelRuntime::cpu().expect("pjrt client");
    for meta in &manifest.artifacts {
        let model = rt.load(meta).expect("load");
        if meta.parity.is_some() {
            model.verify_parity().unwrap_or_else(|e| panic!("{e}"));
        } else {
            // still must execute with zeros and produce the right shape
            let ids = vec![0i32; meta.ids_len()];
            let out = model.run_ids(&ids).expect("run");
            assert_eq!(out.len(), meta.output_len());
        }
    }
}

#[test]
fn repeated_execution_is_deterministic() {
    let manifest = ArtifactManifest::load(default_artifacts_dir()).expect("manifest");
    let meta = &manifest.artifacts[0];
    let rt = ModelRuntime::cpu().expect("pjrt client");
    let model = rt.load(meta).expect("load");
    let ids: Vec<i32> = (0..meta.ids_len() as i32).map(|i| i % 40).collect();
    let a = model.run_ids(&ids).expect("run a");
    let b = model.run_ids(&ids).expect("run b");
    assert_eq!(a, b, "weights buffers must be reusable across calls");
}
