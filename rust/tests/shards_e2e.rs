//! End-to-end tests for the sharded serving tier
//! (`coordinator/shards.rs`): a [`ShardRouter`] pooled over real
//! in-process v2 servers, exercised through shard death, recovery,
//! deadline propagation, and chaos fault injection.
//!
//! The invariant under test everywhere: **nothing admitted is lost** —
//! every request the router accepts resolves to exactly one typed
//! answer (a correct `Response` or an `EngineError`), across shard
//! kills, garbled frames, and dropped connections.

use std::sync::Arc;
use std::time::{Duration, Instant};

use datamux::coordinator::server::{Server, ServerConfig};
use datamux::coordinator::{
    EngineBuilder, EngineError, FaultPlan, InferenceRequest, Placement, ShardConfig, ShardRouter,
    ShardState, Submit, SubmitError,
};
use datamux::runtime::FakeBackend;

const SEQ_LEN: usize = 8;
const N_CLASSES: usize = 3;

/// One in-process shard: a v2 server over a deterministic FakeBackend.
/// `addr` "127.0.0.1:0" picks a free port; a concrete addr rebinds it
/// (shard restart).
fn shard_at(addr: &str, n_classes: usize, delay: Duration) -> Server {
    let mut fake = FakeBackend::new("cls", 2, 1, SEQ_LEN, n_classes);
    if !delay.is_zero() {
        fake = fake.with_delay(delay);
    }
    let engine: Arc<dyn Submit> = Arc::new(
        EngineBuilder::new().max_wait_ms(0).queue_cap(512).build_backend(Arc::new(fake)).unwrap(),
    );
    Server::start(
        engine,
        ServerConfig { addr: addr.into(), max_connections: 16, ..Default::default() },
    )
    .unwrap()
}

fn shard(delay: Duration) -> (Server, String) {
    let srv = shard_at("127.0.0.1:0", N_CLASSES, delay);
    let addr = srv.local_addr.to_string();
    (srv, addr)
}

/// Fast-probe config so breaker transitions happen on test timescales.
fn fast_cfg(addrs: Vec<String>) -> ShardConfig {
    ShardConfig::new(addrs)
        .placement(Placement::RoundRobin)
        .probe_interval(Duration::from_millis(50))
        .probe_timeout(Duration::from_millis(250))
        .backoff(Duration::from_millis(50), Duration::from_millis(200))
        .connect_timeout(Duration::from_millis(500))
        .startup_timeout(Duration::from_secs(5))
        .hop_timeout(Duration::from_secs(2))
        .fault(FaultPlan::disabled())
}

/// A framed classify row (`[CLS] .. [SEP]`) whose fake-model class is
/// known in advance — correctness proof that failover never crosses
/// wires between requests.
fn row(i: usize) -> Vec<i32> {
    vec![1, 44 + (i % 200) as i32, 44 + ((i * 7) % 200) as i32, 2]
}

fn wait_for_state(router: &ShardRouter, shard: usize, want: ShardState, max: Duration) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < max {
        if router.shard_status()[shard].state == want {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

#[test]
fn failover_loses_nothing_across_a_shard_kill_and_readopts_it() {
    // service time > 0 so the kill lands while requests are in flight
    let (srv0, addr0) = shard(Duration::from_millis(5));
    let (srv1, addr1) = shard(Duration::from_millis(5));
    let router =
        Arc::new(ShardRouter::connect(fast_cfg(vec![addr0.clone(), addr1.clone()])).unwrap());
    assert_eq!(router.n_shards(), 2);

    let total = 60;
    let mut handles = Vec::with_capacity(total);
    let mut victim = Some(srv0);
    for i in 0..total {
        if i == total / 3 {
            victim.take().unwrap().stop(); // kill shard 0 mid-stream
        }
        let req = InferenceRequest::classify_framed(row(i));
        handles.push((i, router.submit(req).expect("survivor keeps admitting")));
    }

    // zero lost: every admitted request resolves, correctly
    for (i, h) in &handles {
        let resp = h
            .wait_timeout(Duration::from_secs(10))
            .expect("an admitted request must resolve")
            .unwrap_or_else(|e| panic!("request {i} failed typed: {e:?}"));
        assert_eq!(
            resp.pred_class(),
            FakeBackend::expected_class(&row(*i), N_CLASSES),
            "request {i} answered with the wrong wires crossed"
        );
    }
    // the dead shard trips its breaker once probes notice
    assert!(
        wait_for_state(&router, 0, ShardState::Open, Duration::from_secs(3)),
        "killed shard never tripped its breaker: {:?}",
        router.shard_status()
    );

    // restart the shard on the same port: the half-open probe re-adopts
    // it and the breaker closes again
    let srv0b = shard_at(&addr0, N_CLASSES, Duration::ZERO);
    assert!(
        wait_for_state(&router, 0, ShardState::Closed, Duration::from_secs(5)),
        "returned shard never re-adopted: {:?}",
        router.shard_status()
    );
    // and it serves traffic again
    let h = router.submit(InferenceRequest::classify_framed(row(7))).unwrap();
    assert!(h.wait_timeout(Duration::from_secs(5)).unwrap().is_ok());

    let st = router.shard_status();
    assert!(st[0].failovers > 0, "in-flight requests must have failed over: {st:?}");
    srv0b.stop();
    srv1.stop();
}

#[test]
fn all_shards_down_is_a_fast_typed_unavailable() {
    let (srv, addr) = shard(Duration::ZERO);
    let router = ShardRouter::connect(fast_cfg(vec![addr])).unwrap();
    let ok = router.submit(InferenceRequest::classify_framed(row(0))).unwrap();
    assert!(ok.wait_timeout(Duration::from_secs(5)).unwrap().is_ok());

    srv.stop();
    assert!(
        wait_for_state(&router, 0, ShardState::Open, Duration::from_secs(3)),
        "dead shard never tripped its breaker"
    );
    // both the blocking and non-blocking paths fail fast and typed —
    // no hanging on a dead pool
    let t0 = Instant::now();
    let err = router.submit(InferenceRequest::classify_framed(row(1))).unwrap_err();
    assert!(matches!(err, SubmitError::Unavailable), "{err:?}");
    let err = router.try_submit(InferenceRequest::classify_framed(row(2))).unwrap_err();
    assert!(matches!(err, SubmitError::Unavailable), "{err:?}");
    assert!(t0.elapsed() < Duration::from_secs(1), "Unavailable must be fast: {:?}", t0.elapsed());
}

#[test]
fn deadlines_shed_typed_at_admission_and_propagate_to_the_shard() {
    // slow shard: 50ms service time
    let (srv, addr) = shard(Duration::from_millis(50));
    let router = ShardRouter::connect(fast_cfg(vec![addr])).unwrap();

    // already-zero budget: typed Expired before any wire traffic
    let err = router
        .submit(InferenceRequest::classify_framed(row(0)).with_deadline(Duration::ZERO))
        .unwrap_err();
    assert!(matches!(err, SubmitError::Expired), "{err:?}");

    // a budget at or under the per-hop RTT margin (2ms default) cannot
    // be met behind the wire: shed Overloaded, fast
    let err = router
        .submit(InferenceRequest::classify_framed(row(1)).with_deadline(Duration::from_millis(1)))
        .unwrap_err();
    assert!(matches!(err, SubmitError::Overloaded), "{err:?}");

    // an admissible budget is forwarded (minus the margin) and the
    // *shard* sheds it in-queue — the typed deadline answer crosses the
    // wire back. Occupy the single worker first so the deadlined
    // request waits out its budget behind a 50ms execution.
    let ahead = router.submit(InferenceRequest::classify_framed(row(9))).unwrap();
    std::thread::sleep(Duration::from_millis(15)); // let `ahead` reach the worker
    let h = router
        .submit(InferenceRequest::classify_framed(row(2)).with_deadline(Duration::from_millis(10)))
        .unwrap();
    let out = h.wait_timeout(Duration::from_secs(5)).expect("must resolve");
    assert!(matches!(out, Err(EngineError::DeadlineExceeded)), "{out:?}");
    assert!(router.counters().expired >= 1, "{:?}", router.counters());
    assert!(ahead.wait_timeout(Duration::from_secs(5)).unwrap().is_ok());

    // a generous budget completes
    let h = router
        .submit(InferenceRequest::classify_framed(row(3)).with_deadline(Duration::from_secs(5)))
        .unwrap();
    assert!(h.wait_timeout(Duration::from_secs(5)).unwrap().is_ok());
    srv.stop();
}

#[test]
fn chaos_faults_never_lose_or_miscorrelate_admitted_requests() {
    let (srv0, addr0) = shard(Duration::ZERO);
    let (srv1, addr1) = shard(Duration::ZERO);
    let cfg = fast_cfg(vec![addr0, addr1]).fault(FaultPlan::chaos(42));
    let router = ShardRouter::connect(cfg).unwrap();

    let total = 80;
    let mut handles = Vec::new();
    for i in 0..total {
        // transient Unavailable (every conn dead for a beat) is a typed
        // admission refusal, not a loss — retry a few times
        for attempt in 0.. {
            match router.submit(InferenceRequest::classify_framed(row(i))) {
                Ok(h) => {
                    handles.push((i, h));
                    break;
                }
                Err(SubmitError::Unavailable) if attempt < 100 => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => panic!("request {i}: unexpected admission error {e:?}"),
            }
        }
    }
    let mut ok = 0usize;
    for (i, h) in &handles {
        match h.wait_timeout(Duration::from_secs(15)).expect("admitted requests must resolve") {
            Ok(resp) => {
                assert_eq!(
                    resp.pred_class(),
                    FakeBackend::expected_class(&row(*i), N_CLASSES),
                    "request {i}: chaos crossed the wires"
                );
                ok += 1;
            }
            // a request can fail typed (bounced past max_resubmits),
            // but never silently
            Err(e) => eprintln!("request {i} failed typed under chaos: {e:?}"),
        }
    }
    assert!(ok > total / 2, "chaos should not stop most progress: {ok}/{total}");
    srv0.stop();
    srv1.stop();
}

#[test]
fn shards_serving_different_models_are_rejected_at_connect() {
    let srv0 = shard_at("127.0.0.1:0", N_CLASSES, Duration::ZERO);
    let srv1 = shard_at("127.0.0.1:0", N_CLASSES + 1, Duration::ZERO);
    let cfg = fast_cfg(vec![srv0.local_addr.to_string(), srv1.local_addr.to_string()])
        .startup_timeout(Duration::from_secs(2));
    let err = ShardRouter::connect(cfg).unwrap_err();
    assert!(err.to_string().contains("different model shape"), "{err:#}");
    srv0.stop();
    srv1.stop();
}

#[test]
fn front_stats_expose_the_shard_pool_and_model_block() {
    use std::io::{BufRead, BufReader, Write};

    let (srv0, addr0) = shard(Duration::ZERO);
    let (srv1, addr1) = shard(Duration::ZERO);
    let router: Arc<dyn Submit> =
        Arc::new(ShardRouter::connect(fast_cfg(vec![addr0, addr1])).unwrap());
    // the front is itself a v2 server whose engine is the shard router
    let front = Server::start(
        router,
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: 4, ..Default::default() },
    )
    .unwrap();

    let mut c = std::net::TcpStream::connect(front.local_addr).unwrap();
    c.write_all(b"{\"id\":1,\"op\":\"classify\",\"ids\":[1,45,46,2]}\n").unwrap();
    let mut rd = BufReader::new(c.try_clone().unwrap());
    let mut line = String::new();
    rd.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");

    c.write_all(b"{\"id\":2,\"op\":\"stats\"}\n").unwrap();
    line.clear();
    rd.read_line(&mut line).unwrap();
    let v = datamux::util::json::Json::parse(&line).unwrap();
    let stats = v.get("stats").expect("stats object");
    let shards = stats.get("shards").and_then(|s| s.as_arr()).expect("shards array");
    assert_eq!(shards.len(), 2, "{line}");
    for sh in shards {
        assert_eq!(sh.get("state").and_then(|s| s.as_str()), Some("closed"), "{line}");
    }
    let model = stats.get("model").expect("model block");
    assert_eq!(model.get("n_classes").and_then(|n| n.as_usize()), Some(N_CLASSES), "{line}");

    front.stop();
    srv0.stop();
    srv1.stop();
}
