//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset the datamux crate uses: `Error`, `Result`,
//! `Context` (on `Result` and `Option`), and the `anyhow!` / `bail!` /
//! `ensure!` macros. Error chains render with `{:#}` like upstream
//! (`outer: inner: root`). Not a general replacement — just enough API
//! surface, implemented with zero dependencies so builds never touch the
//! network.

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error with a context chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
    /// contexts added via `.context(..)`, innermost first
    context: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None, context: Vec::new() }
    }

    pub fn new<E: StdError + Send + Sync + 'static>(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)), context: Vec::new() }
    }

    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.context.push(c.to_string());
        self
    }

    /// Full chain, outermost first, `: `-joined (what `{:#}` prints).
    fn chain_string(&self) -> String {
        let mut parts: Vec<String> = self.context.iter().rev().cloned().collect();
        parts.push(self.msg.clone());
        let mut src = self.source.as_ref().and_then(|s| s.source());
        while let Some(s) = src {
            parts.push(s.to_string());
            src = s.source();
        }
        parts.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain_string())
        } else {
            write!(f, "{}", self.context.last().unwrap_or(&self.msg))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain_string())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion (used by `?`) does not overlap the reflexive
// `From<T> for T` — same trick as upstream anyhow.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::new(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::new(io_err()).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("doing {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "doing x: root cause");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {} to be true", "ok");
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(format!("{}", f(false).unwrap_err()), "wanted ok to be true");
        let e = anyhow!("x={}", 3);
        assert_eq!(format!("{e}"), "x=3");
    }
}
