//! Offline stub of the `xla` (PJRT) crate surface used by
//! `datamux::runtime::model`.
//!
//! This image has no PJRT plugin, so every entry point fails at
//! `PjRtClient::cpu()` with a clear message; nothing downstream is
//! reachable. The serving stack remains fully testable through
//! `datamux::runtime::FakeBackend`, which bypasses PJRT entirely. Swap
//! this path dependency for the real `xla` crate to execute AOT
//! artifacts.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT backend not available in this offline build; serve through \
         datamux::runtime::FakeBackend or link the real `xla` crate"
            .to_string(),
    )
}

#[derive(Clone)]
pub struct PjRtClient(());

pub struct PjRtDevice(());

pub struct PjRtBuffer(());

pub struct PjRtLoadedExecutable(());

pub struct HloModuleProto(());

pub struct XlaComputation(());

pub struct Literal(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{e:?}").contains("not available"));
    }
}
